//! Per-sequence cache state: block table + validity + scores.
//!
//! This is the host-side single source of truth for what the decode graph
//! sees. The serialization the runtime feeds the graph — the `i32` block
//! table and the `[NB * B]` validity mask — is maintained **incrementally**
//! as persistent buffers updated in place by every mutation:
//!
//!   * `append` flips one mask float;
//!   * `evict_block` shifts a suffix of both buffers (the paper's "table
//!     shuffle only" decode-step overhead);
//!   * `kill_token` clears one mask float;
//!   * `grow` zero-extends both buffers.
//!
//! Steady-state decode therefore serializes graph inputs with **zero heap
//! allocations**: [`SeqCache::block_table`] / [`SeqCache::valid_mask`] are
//! borrow-based O(1) accessors, with dirty-region tracking
//! ([`SeqCache::table_dirty`] / [`SeqCache::mask_dirty`]) so a
//! device-resident-metadata backend can upload only what changed. The
//! allocating `block_table_i32` / `valid_mask_f32` methods survive as thin
//! compatibility wrappers, and `rebuild_*` keep the original from-scratch
//! scan as the property-test/bench baseline.
//!
//! **Prefix caching.** [`SeqCache::try_load_prefill_cached`] walks the
//! arena's content-hash prefix index ([`prefix_block_hashes`]: a hash
//! chained over `(parent_hash, block entries)`, full blocks only) and maps
//! every leading hit into this sequence's local slot space read-only —
//! refcount + 1 on a page some other sequence already holds, zero new
//! arena blocks — then materializes only the uncached tail, publishing its
//! full blocks for the next prompt. The table/mask serialization is
//! bit-identical to the uncached path (property-tested): sharing is pure
//! arena accounting, invisible to the decode graph. Any in-place content
//! mutation (token kill) goes through [`SeqCache::make_private`] first —
//! copy-on-write while the page is shared (refcount > 1), unpublish when
//! this sequence is the sole holder — so no policy ever prunes a shared
//! page in place; whole-block eviction simply releases this sequence's
//! reference (the page lives on for its other holders).

use super::block::Block;
use super::block_manager::{BlockManager, SeqId};
use super::stats::CacheStats;

/// Number of importance channels carried per token
/// (0 = V/K ratio, 1 = key L2 norm, 2 = KeyDiff cosine).
pub const SCORE_CHANNELS: usize = 3;

/// The per-token score-channel layout a cache was serialized under. Its
/// [`ChannelLayout::tag`] is folded into the prefix-hash chain seed, so
/// two builds that pack a different channel COUNT — or reinterpret what a
/// channel means (a `version` bump) — can never alias each other's pages
/// in the shared prefix index: the hash bytes would line up, the
/// semantics would not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelLayout {
    /// Score channels carried per token entry.
    pub channels: u32,
    /// Bump when a channel's MEANING changes without its count changing
    /// (e.g. if channel 1 switched from key L2 to value L2).
    pub version: u32,
}

/// The layout every current cache serializes under: [`SCORE_CHANNELS`]
/// channels, semantics version 1.
pub const SCORE_LAYOUT_V1: ChannelLayout =
    ChannelLayout { channels: SCORE_CHANNELS as u32, version: 1 };

impl ChannelLayout {
    /// The layout's contribution to the hash-chain seed. Channel count
    /// and version live in disjoint halves, so no two distinct layouts
    /// share a tag.
    pub fn tag(&self) -> u64 {
        (u64::from(self.channels) << 32) | u64::from(self.version)
    }
}

/// SplitMix64 finalizer — the mixing core of the prefix-block hash chain.
fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Chained content hashes of the FULL blocks of a packed prefill stream:
/// `hash[b]` covers every entry of blocks `0..=b` (positions, score bits
/// and the caller's per-entry content `keys` — e.g. a hash of the raw
/// token id), so equal hashes mean equal prefix content end to end. The
/// partial tail block (if any) is never hashed: only full, append-closed
/// blocks are shareable. This is the key the arena's prefix index is
/// published and probed under.
pub fn prefix_block_hashes(
    block_size: usize,
    tokens: &[(u32, [f32; 3])],
    keys: &[u64],
) -> Vec<u64> {
    prefix_block_hashes_with_layout(SCORE_LAYOUT_V1, block_size, tokens, keys)
}

/// [`prefix_block_hashes`] under an explicit [`ChannelLayout`] — the seed
/// binds (block size, channel layout), so the same entries paged
/// differently, packed with a different channel count, or reinterpreted
/// under a new channel-semantics version never collide.
pub fn prefix_block_hashes_with_layout(
    layout: ChannelLayout,
    block_size: usize,
    tokens: &[(u32, [f32; 3])],
    keys: &[u64],
) -> Vec<u64> {
    assert_eq!(tokens.len(), keys.len(), "one content key per entry");
    let n_full = tokens.len() / block_size;
    let mut out = Vec::with_capacity(n_full);
    // chain seed binds the block size and the channel layout: two mixing
    // rounds so the (size, layout) pair feeds the chain injectively
    let mut chain = mix64(0x70ae_51ca_0b10_c457 ^ block_size as u64);
    chain = mix64(chain ^ layout.tag());
    for b in 0..n_full {
        for i in b * block_size..(b + 1) * block_size {
            let (pos, sc) = tokens[i];
            chain = mix64(chain ^ keys[i]);
            chain = mix64(chain ^ (u64::from(pos) << 1) ^ 1);
            for s in sc {
                chain = mix64(chain ^ u64::from(s.to_bits()));
            }
        }
        out.push(chain);
    }
    out
}

/// Half-open dirty interval `[lo, hi)` over a serialization buffer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct DirtyRange {
    lo: usize,
    hi: usize,
}

impl DirtyRange {
    fn full(len: usize) -> Self {
        DirtyRange { lo: 0, hi: len }
    }

    fn is_empty(&self) -> bool {
        self.lo >= self.hi
    }

    fn as_range(&self) -> Option<std::ops::Range<usize>> {
        if self.is_empty() {
            None
        } else {
            Some(self.lo..self.hi)
        }
    }

    fn mark(&mut self, lo: usize, hi: usize) {
        if lo >= hi {
            return;
        }
        if self.is_empty() {
            self.lo = lo;
            self.hi = hi;
        } else {
            self.lo = self.lo.min(lo);
            self.hi = self.hi.max(hi);
        }
    }

    fn clear(&mut self) {
        self.lo = 0;
        self.hi = 0;
    }
}

/// Host-side snapshot of a sequence's complete cache state — block
/// contents (per-token scores, positions, liveness bitmaps), the
/// incrementally maintained block table and validity mask, the local
/// free-slot list and the cache counters. Captured by
/// [`SeqCache::snapshot`] when the scheduler swaps a preemption victim to
/// host instead of discarding it, and rebuilt by
/// [`SeqCache::restore_from`] against fresh arena pages on readmission.
///
/// The snapshot never touches the device path: it holds exactly the
/// host-side metadata the eviction machinery runs on. Local device slots
/// (`Block::phys`) are preserved verbatim, so the restored block table and
/// mask are bit-identical to the suspended ones; only the global arena
/// pages (`Block::arena_slot`) are reassigned at restore time.
#[derive(Debug, Clone)]
pub struct KvSnapshot {
    block_size: usize,
    bucket_blocks: usize,
    blocks: Vec<Block>,
    local_free: Vec<usize>,
    next_position: u32,
    partial_count: usize,
    table: Vec<i32>,
    mask: Vec<f32>,
    stats: CacheStats,
}

impl KvSnapshot {
    /// Arena blocks a restore will claim.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn bucket_blocks(&self) -> usize {
        self.bucket_blocks
    }

    /// Approximate host bytes this snapshot pins — what a bounded swap
    /// pool accounts. Dominated by the per-block token payload (3 score
    /// channels + positions) and the serialization buffers.
    pub fn host_bytes(&self) -> usize {
        let per_block = std::mem::size_of::<Block>()
            + self.block_size * (SCORE_CHANNELS + 1) * std::mem::size_of::<f32>();
        std::mem::size_of::<Self>()
            + self.blocks.len() * per_block
            + self.table.len() * std::mem::size_of::<i32>()
            + self.mask.len() * std::mem::size_of::<f32>()
            + self.local_free.len() * std::mem::size_of::<usize>()
    }
}

/// Why an append cannot proceed right now (see
/// [`SeqCache::try_ensure_block`]). The two failure modes demand different
/// remedies: a full bucket needs the runtime to migrate the sequence to a
/// larger device buffer; a dry arena needs the scheduler to preempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockAlloc {
    /// A write slot exists (possibly just allocated).
    Ready,
    /// The sequence's serialization bucket is full — grow the bucket.
    BucketFull,
    /// The shared arena has no free block — preempt or wait.
    ArenaDry,
}

#[derive(Debug)]
pub struct SeqCache {
    block_size: usize,
    /// Shared physical arena this sequence allocates from.
    mgr: BlockManager,
    seq: SeqId,
    /// Serialization capacity in blocks (= the device bucket the graphs
    /// see). Distinct from the arena capacity: a sequence's bucket can be
    /// smaller or larger than the globally free block count.
    bucket_blocks: usize,
    /// True when this cache was created with its own single-tenant arena
    /// (`SeqCache::new`); `grow` then extends the arena alongside the
    /// bucket, preserving the historical standalone semantics.
    owns_arena: bool,
    /// Free slots inside this sequence's device bucket (LIFO, seeded in
    /// reverse so slot 0 is handed out first). Block-table entries index
    /// the sequence's own device buffer, so they come from here; the
    /// arena's global page ids ride along in `Block::arena_slot`.
    local_free: Vec<usize>,
    /// Logical block order (oldest first). `blocks[i].phys` is the slot.
    blocks: Vec<Block>,
    /// Highest sequence position written so far + 1 (monotonic; survives
    /// eviction — RoPE positions are original positions).
    next_position: u32,
    /// Running count of fragmented (partially dead) pages, maintained
    /// incrementally so `partial_blocks()` and the per-kill peak update
    /// are O(1) instead of an O(blocks) rescan.
    partial_count: usize,
    /// Persistent logical->physical table, `len == capacity_blocks()`;
    /// entries at logical indices >= `blocks.len()` are 0 padding.
    table: Vec<i32>,
    /// Persistent validity mask, `len == capacity_blocks() * block_size`,
    /// logical layout; slots outside live blocks stay 0.0.
    mask: Vec<f32>,
    table_dirty: DirtyRange,
    mask_dirty: DirtyRange,
    pub stats: CacheStats,
}

impl SeqCache {
    /// Standalone cache with a private single-tenant arena of
    /// `capacity_blocks` slots — the historical constructor, used by the
    /// simulator, policy unit tests and one-shot generation.
    pub fn new(block_size: usize, capacity_blocks: usize) -> Self {
        let mgr = BlockManager::new(capacity_blocks);
        let mut c = Self::new_shared(block_size, capacity_blocks, &mgr);
        c.owns_arena = true;
        c
    }

    /// Cache allocating from a shared `arena`, with a serialization bucket
    /// of `bucket_blocks` (the device-buffer capacity the decode graphs
    /// are padded to). The sequence's blocks return to the arena when the
    /// cache is dropped (retire or preemption).
    pub fn new_shared(block_size: usize, bucket_blocks: usize, arena: &BlockManager) -> Self {
        SeqCache {
            block_size,
            mgr: arena.clone(),
            seq: arena.register(),
            bucket_blocks,
            owns_arena: false,
            local_free: (0..bucket_blocks).rev().collect(),
            blocks: Vec::new(),
            next_position: 0,
            partial_count: 0,
            table: vec![0; bucket_blocks],
            mask: vec![0.0; bucket_blocks * block_size],
            table_dirty: DirtyRange::full(bucket_blocks),
            mask_dirty: DirtyRange::full(bucket_blocks * block_size),
            stats: CacheStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.bucket_blocks
    }

    /// Handle to the arena this sequence allocates from.
    pub fn arena(&self) -> &BlockManager {
        &self.mgr
    }

    pub fn seq_id(&self) -> SeqId {
        self.seq
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    /// Free blocks in the (shared) arena — O(1).
    pub fn free_blocks(&self) -> usize {
        self.mgr.free_count()
    }

    /// Live (attention-visible) tokens.
    pub fn live_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.live_count()).sum()
    }

    /// Tokens ever written and not yet block-evicted (incl. dead ones).
    pub fn held_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.fill).sum()
    }

    /// Allocated-but-fragmented pages (paper Limitation 1 metric). O(1):
    /// maintained incrementally by `kill_token`/`evict_block`.
    pub fn partial_blocks(&self) -> usize {
        self.partial_count
    }

    /// live / allocated-slot tokens; 1.0 = perfectly packed.
    pub fn occupancy(&self) -> f64 {
        let alloc = self.blocks.len() * self.block_size;
        if alloc == 0 {
            return 1.0;
        }
        self.live_tokens() as f64 / alloc as f64
    }

    pub fn next_position(&self) -> u32 {
        self.next_position
    }

    /// True when the newest block is full (or none exists) — i.e. the next
    /// append needs a fresh block. This is the paper's decode-phase
    /// eviction trigger (`L % B == 0`).
    pub fn last_block_full(&self) -> bool {
        self.blocks.last().map_or(true, |b| b.fill == self.block_size)
    }

    /// Whether an append right now would need a block the current bucket
    /// cannot hold (runtime must migrate to a larger device bucket).
    pub fn needs_grow(&self) -> bool {
        self.last_block_full() && self.blocks.len() == self.bucket_blocks
    }

    /// Append a new logical block at device slot `local`, backed by arena
    /// page `arena_slot`, and mirror it into the persistent table. The
    /// mask region for the new logical index is already all-zero (tail
    /// invariant maintained by `remove_block_at`), so no mask write is
    /// needed.
    fn push_new_block(&mut self, local: usize, arena_slot: usize) {
        let li = self.blocks.len();
        let mut blk = Block::new(local, self.block_size);
        blk.arena_slot = arena_slot;
        self.blocks.push(blk);
        self.table[li] = local as i32;
        self.table_dirty.mark(li, li + 1);
        self.stats.peak_live_blocks = self.stats.peak_live_blocks.max(self.blocks.len() as u64);
    }

    /// Drop logical block `idx` and shift the suffix of both persistent
    /// buffers down by one block — the only O(blocks) metadata move in the
    /// structured-eviction path. Restores the all-zero tail invariant.
    fn remove_block_at(&mut self, idx: usize) -> Block {
        let n = self.blocks.len();
        let blk = self.blocks.remove(idx);
        let bs = self.block_size;
        self.table.copy_within(idx + 1..n, idx);
        self.table[n - 1] = 0;
        self.table_dirty.mark(idx, n);
        self.mask.copy_within((idx + 1) * bs..n * bs, idx * bs);
        self.mask[(n - 1) * bs..n * bs].fill(0.0);
        self.mask_dirty.mark(idx * bs, n * bs);
        blk
    }

    // -- append path --------------------------------------------------------

    /// Physical flat slot (block * B + offset) where the NEXT token will be
    /// written. Allocates nothing; errors if a new block is needed but the
    /// pool is empty.
    pub fn peek_write_slot(&self) -> Option<usize> {
        if self.last_block_full() {
            None // needs alloc first; use ensure_block()
        } else {
            let b = self.blocks.last().unwrap();
            Some(b.phys * self.block_size + b.fill)
        }
    }

    /// Make sure a block with a free slot exists, allocating from the
    /// arena when the newest block is full. The two failure modes are
    /// distinct: [`BlockAlloc::BucketFull`] means the serialization bucket
    /// must grow (device-buffer migration), [`BlockAlloc::ArenaDry`] means
    /// global KV memory is exhausted (scheduler preempts).
    pub fn try_ensure_block(&mut self) -> BlockAlloc {
        if !self.last_block_full() {
            return BlockAlloc::Ready;
        }
        if self.local_free.is_empty() {
            return BlockAlloc::BucketFull;
        }
        match self.mgr.alloc(self.seq) {
            Some(arena_slot) => {
                let local = self.local_free.pop().expect("bucket accounting broken");
                self.push_new_block(local, arena_slot);
                self.stats.blocks_allocated += 1;
                self.stats.table_updates += 1;
                BlockAlloc::Ready
            }
            None => BlockAlloc::ArenaDry,
        }
    }

    /// Boolean convenience over [`SeqCache::try_ensure_block`]: `false` on
    /// either failure mode (callers that grow-on-demand keep working).
    pub fn ensure_block(&mut self) -> bool {
        self.try_ensure_block() == BlockAlloc::Ready
    }

    /// Record the token the decode step just wrote at `peek_write_slot`.
    /// Serialization cost: one mask float flip.
    pub fn append(&mut self, scores: [f32; 3]) {
        assert!(!self.last_block_full(), "append without ensure_block()");
        let pos = self.next_position;
        let li = self.blocks.len() - 1;
        let off = self.blocks.last_mut().unwrap().push(pos, scores);
        let slot = li * self.block_size + off;
        self.mask[slot] = 1.0;
        self.mask_dirty.mark(slot, slot + 1);
        self.next_position += 1;
        self.stats.tokens_written += 1;
    }

    /// Bulk-load a prefilled, already-evicted prompt: `tokens[i]` is
    /// (original_position, [3]scores), laid out contiguously in logical
    /// order (matching the runtime's host-side pack). Every prompt block
    /// is claimed in ONE [`BlockManager::alloc_many`] call — a single
    /// global-lock acquisition regardless of prompt length (pinned by the
    /// lock-count test). Fails without side effects — all-or-nothing: a
    /// `BucketFull`/`ArenaDry` prompt claims no blocks at all.
    pub fn try_load_prefill(
        &mut self,
        tokens: &[(u32, [f32; 3])],
        total_prompt_len: u32,
    ) -> Result<(), BlockAlloc> {
        assert!(self.blocks.is_empty(), "load_prefill on non-empty cache");
        let bs = self.block_size;
        let need = (tokens.len() + bs - 1) / bs;
        if need > self.local_free.len() {
            return Err(BlockAlloc::BucketFull);
        }
        let Some(slots) = self.mgr.alloc_many(self.seq, need) else {
            return Err(BlockAlloc::ArenaDry);
        };
        for (i, chunk) in tokens.chunks(bs).enumerate() {
            let local = self.local_free.pop().expect("bucket accounting broken");
            self.push_new_block(local, slots[i]);
            self.stats.blocks_allocated += 1;
            let blk = self.blocks.last_mut().unwrap();
            for (pos, sc) in chunk {
                let off = blk.push(*pos, *sc);
                self.mask[i * bs + off] = 1.0;
            }
        }
        self.mask_dirty.mark(0, self.blocks.len() * bs);
        self.stats.tokens_written += tokens.len() as u64;
        self.stats.table_updates += 1;
        self.next_position = total_prompt_len;
        Ok(())
    }

    /// Panicking convenience over [`SeqCache::try_load_prefill`] for
    /// callers that sized the bucket themselves (simulator, tests).
    pub fn load_prefill(&mut self, tokens: &[(u32, [f32; 3])], total_prompt_len: u32) {
        self.try_load_prefill(tokens, total_prompt_len)
            .expect("prefill exceeds bucket/arena");
    }

    /// Prefix-cached prefill: like [`SeqCache::try_load_prefill`], but
    /// walks the arena's content-hash prefix index first. Every LEADING
    /// full block whose chain hash ([`prefix_block_hashes`]; `keys[i]` is
    /// the caller's per-entry content key) is already published gets
    /// mapped into this sequence's slot space by reference — refcount + 1
    /// on the existing page, no arena allocation, no K/V
    /// re-materialization — and only the uncached tail is loaded the
    /// normal way, with its own full blocks published for the next prompt.
    ///
    /// The resulting block table, validity mask and live-token view are
    /// bit-identical to the uncached path (property-tested): sharing is
    /// pure physical-page accounting. Returns the number of hit blocks
    /// (also recorded in `stats.prefix_hit_blocks`). On failure the claims
    /// made so far stay owned by this sequence; dropping the cache
    /// releases them (shared pages by refcount).
    pub fn try_load_prefill_cached(
        &mut self,
        tokens: &[(u32, [f32; 3])],
        keys: &[u64],
        total_prompt_len: u32,
    ) -> Result<usize, BlockAlloc> {
        assert!(self.blocks.is_empty(), "load_prefill on non-empty cache");
        let bs = self.block_size;
        let hashes = prefix_block_hashes(bs, tokens, keys);

        // -- map every leading published block by reference (one lock) --
        let shared = self.mgr.acquire_shared_run(self.seq, &hashes);
        let hits = shared.len();
        // bucket check up front: the hit blocks plus the uncached tail
        let tail_need = (tokens.len() - hits * bs + bs - 1) / bs;
        if hits + tail_need > self.local_free.len() {
            return Err(BlockAlloc::BucketFull);
        }
        for (i, &arena_slot) in shared.iter().enumerate() {
            let local = self.local_free.pop().expect("bucket accounting broken");
            self.push_new_block(local, arena_slot);
            let blk = self.blocks.last_mut().unwrap();
            blk.prefix_tracked = true;
            for (pos, sc) in &tokens[i * bs..(i + 1) * bs] {
                let off = blk.push(*pos, *sc);
                debug_assert_eq!(off + 1, blk.fill);
            }
            self.mask[i * bs..(i + 1) * bs].fill(1.0);
        }
        self.stats.prefix_hit_blocks += hits as u64;

        // -- materialize the uncached tail exactly like the uncached path,
        //    claiming every tail block under one lock --
        let Some(slots) = self.mgr.alloc_many(self.seq, tail_need) else {
            return Err(BlockAlloc::ArenaDry); // hit claims stay owned; drop releases
        };
        for (j, chunk) in tokens[hits * bs..].chunks(bs).enumerate() {
            let local = self.local_free.pop().expect("bucket accounting broken");
            self.push_new_block(local, slots[j]);
            self.stats.blocks_allocated += 1;
            let blk = self.blocks.last_mut().unwrap();
            for (pos, sc) in chunk {
                let off = blk.push(*pos, *sc);
                self.mask[(hits + j) * bs + off] = 1.0;
            }
        }
        self.mask_dirty.mark(0, self.blocks.len() * bs);
        self.stats.tokens_written += tokens.len() as u64;
        self.stats.table_updates += 1;
        self.next_position = total_prompt_len;

        // -- publish the freshly materialized full blocks (one lock) --
        let fresh: Vec<(usize, u64)> = (hits..hashes.len())
            .map(|b| (self.blocks[b].arena_slot, hashes[b]))
            .collect();
        for (k, ok) in self.mgr.publish_many(self.seq, &fresh).into_iter().enumerate() {
            if ok {
                self.blocks[hits + k].prefix_tracked = true;
            }
        }
        Ok(hits)
    }

    // -- eviction primitives -------------------------------------------------

    /// Copy the shared page behind block `idx` into a fresh private arena
    /// page (the copy-on-write). `phys` — the local device slot the block
    /// table serializes — is untouched: in the device story the sequence's
    /// bucket-local copy already exists, only the global page claim moves.
    fn cow_block(&mut self, idx: usize) -> Result<(), BlockAlloc> {
        let fresh = match self.mgr.alloc(self.seq) {
            Some(p) => p,
            None => return Err(BlockAlloc::ArenaDry),
        };
        let shared = self.blocks[idx].arena_slot;
        self.mgr.release(self.seq, shared); // other holders keep the page
        self.blocks[idx].arena_slot = fresh;
        self.blocks[idx].prefix_tracked = false;
        self.stats.cow_copies += 1;
        Ok(())
    }

    /// Make block `idx` safe for in-place content mutation: while its
    /// arena page is shared (refcount > 1) the page is frozen, so the
    /// writer copies-on-write onto a fresh private page; a sole holder
    /// instead removes the page from the prefix index (the published hash
    /// is about to stop describing the content). Returns whether a copy
    /// was made. `Err(ArenaDry)` — with nothing changed — when the
    /// copy-on-write cannot claim a page; the scheduler avoids this by
    /// unsharing up front while it can still preempt (see
    /// `DecodeBackend::prepare_round`).
    ///
    /// The refcount check and the unpublish/copy are separate arena-lock
    /// acquisitions: mutation decisions assume the single engine thread
    /// that owns every `SeqCache` of an arena (today's scheduler). A
    /// future multi-worker engine must fold check + act into one locked
    /// arena operation before prefills can race against writers.
    pub fn make_private(&mut self, idx: usize) -> Result<bool, BlockAlloc> {
        if !self.blocks[idx].prefix_tracked {
            return Ok(false);
        }
        let slot = self.blocks[idx].arena_slot;
        if self.mgr.refcount(slot) > 1 {
            self.cow_block(idx)?;
            Ok(true)
        } else {
            self.mgr.unpublish_slot(slot);
            self.blocks[idx].prefix_tracked = false;
            Ok(false)
        }
    }

    /// Copy-on-write every block whose arena page is currently shared
    /// (refcount > 1), leaving sole-holder published pages in the index
    /// untouched (they unpublish lazily on the first actual write). Called
    /// by backends during round reservation for policies that hole-punch
    /// tokens inside existing pages, so the fallible part of copy-on-write
    /// happens while the scheduler can still preempt on `ArenaDry`.
    /// Returns the number of copies made.
    pub fn unshare_shared_blocks(&mut self) -> Result<usize, BlockAlloc> {
        let mut copies = 0;
        for idx in 0..self.blocks.len() {
            if self.blocks[idx].prefix_tracked
                && self.mgr.refcount(self.blocks[idx].arena_slot) > 1
            {
                self.cow_block(idx)?;
                copies += 1;
            }
        }
        Ok(copies)
    }

    /// Structured eviction: drop logical block `idx` entirely. O(blocks)
    /// table shift, zero device-data movement. Releases this sequence's
    /// claim on the physical page — a page other sequences still share
    /// stays allocated (and published) for them; only the last holder's
    /// eviction frees it. No copy-on-write is ever needed here: dropping a
    /// reference mutates nothing in place.
    pub fn evict_block(&mut self, idx: usize) {
        let blk = self.remove_block_at(idx);
        if blk.is_partial() {
            self.partial_count -= 1;
        }
        self.stats.tokens_evicted += blk.live_count() as u64;
        self.stats.blocks_evicted += 1;
        self.stats.table_updates += 1;
        self.mgr.release(self.seq, blk.arena_slot);
        self.local_free.push(blk.phys);
    }

    /// Unstructured eviction: kill one token at (logical block, offset) —
    /// one mask float flip. Frees the block only once every token in it is
    /// dead. A kill mutates page content in place, so a shared page is
    /// copied-on-write first ([`SeqCache::make_private`]); `Err(ArenaDry)`
    /// — with the token still alive — when that copy cannot claim a page.
    pub fn try_kill_token(&mut self, block_idx: usize, off: usize) -> Result<(), BlockAlloc> {
        self.make_private(block_idx)?;
        let was_partial = self.blocks[block_idx].is_partial();
        let killed = self.blocks[block_idx].kill(off);
        assert!(killed, "killing dead token ({block_idx},{off})");
        if !was_partial {
            // a successful kill always leaves live < fill
            self.partial_count += 1;
        }
        let slot = block_idx * self.block_size + off;
        self.mask[slot] = 0.0;
        self.mask_dirty.mark(slot, slot + 1);
        self.stats.tokens_evicted += 1;
        self.stats.mask_updates += 1;
        if self.blocks[block_idx].is_empty() {
            // Whole page finally drained — only now can it be reused.
            self.partial_count -= 1;
            let blk = self.remove_block_at(block_idx);
            self.mgr.release(self.seq, blk.arena_slot);
            self.local_free.push(blk.phys);
            self.stats.blocks_evicted += 1;
            self.stats.table_updates += 1;
        }
        self.stats.peak_partial_blocks =
            self.stats.peak_partial_blocks.max(self.partial_count as u64);
        Ok(())
    }

    /// Panicking convenience over [`SeqCache::try_kill_token`] for callers
    /// that guarantee copy-on-write headroom themselves (the scheduler
    /// unshares killing sequences during reservation; standalone/test
    /// callers run against roomy arenas).
    pub fn kill_token(&mut self, block_idx: usize, off: usize) {
        if let Err(e) = self.try_kill_token(block_idx, off) {
            panic!(
                "kill_token({block_idx},{off}): copy-on-write of a shared page \
                 failed ({e:?}); unshare before killing (DecodeBackend::prepare_round)"
            );
        }
    }

    /// Bucket growth: runtime migrated the device buffer to a bigger
    /// capacity. Zero-extends the persistent serialization buffers. Does
    /// NOT create arena capacity in shared mode — physical memory is the
    /// scheduler's to manage; a standalone cache (private arena) grows its
    /// arena alongside, preserving the historical semantics.
    pub fn grow(&mut self, new_capacity_blocks: usize) {
        let old_cap = self.bucket_blocks;
        assert!(new_capacity_blocks >= old_cap, "bucket cannot shrink");
        self.bucket_blocks = new_capacity_blocks;
        for p in (old_cap..new_capacity_blocks).rev() {
            self.local_free.push(p);
        }
        if self.owns_arena {
            self.mgr.grow(new_capacity_blocks);
        }
        self.table.resize(new_capacity_blocks, 0);
        self.mask.resize(new_capacity_blocks * self.block_size, 0.0);
        self.table_dirty.mark(old_cap, new_capacity_blocks);
        self.mask_dirty
            .mark(old_cap * self.block_size, new_capacity_blocks * self.block_size);
        self.stats.bucket_grows += 1;
    }

    // -- graph-input serialization -------------------------------------------

    /// Logical->physical table, padded with 0 to `nb` entries (padding is
    /// masked out via the validity mask so its value is irrelevant).
    /// Borrow of the incrementally maintained buffer — O(1), no allocation.
    /// `nb` must not exceed `capacity_blocks()` (use the `_i32` wrapper for
    /// oversized pads).
    pub fn block_table(&self, nb: usize) -> &[i32] {
        assert!(self.blocks.len() <= nb, "table exceeds bucket");
        assert!(
            nb <= self.table.len(),
            "bucket {nb} beyond capacity {}",
            self.table.len()
        );
        &self.table[..nb]
    }

    /// Validity mask in logical order, flattened [nb * B]. Borrow of the
    /// incrementally maintained buffer — O(1), no allocation. `nb` must not
    /// exceed `capacity_blocks()`.
    pub fn valid_mask(&self, nb: usize) -> &[f32] {
        assert!(self.blocks.len() <= nb, "mask exceeds bucket");
        assert!(
            nb <= self.bucket_blocks,
            "bucket {nb} beyond capacity {}",
            self.bucket_blocks
        );
        &self.mask[..nb * self.block_size]
    }

    /// Run `f` over the validity mask (padded to `nb` blocks) with `slot`
    /// temporarily forced to 1.0 — the decode graph's view including the
    /// incoming token, which `append` commits for real after the step
    /// executes. The committed value is restored before returning, so the
    /// incremental buffers never drift; `f` borrows the persistent buffer
    /// directly and no copy is made.
    pub fn with_incoming_mask<R>(
        &mut self,
        nb: usize,
        slot: usize,
        f: impl FnOnce(&[f32]) -> R,
    ) -> R {
        let prev = self.mask[slot];
        self.mask[slot] = 1.0;
        let r = f(&self.mask[..nb * self.block_size]);
        self.mask[slot] = prev;
        r
    }

    /// Dirty region of the block table (entry indices) since the last
    /// [`SeqCache::clear_dirty`]; `None` when nothing changed.
    pub fn table_dirty(&self) -> Option<std::ops::Range<usize>> {
        self.table_dirty.as_range()
    }

    /// Dirty region of the validity mask (flat slot indices) since the last
    /// [`SeqCache::clear_dirty`]; `None` when nothing changed.
    pub fn mask_dirty(&self) -> Option<std::ops::Range<usize>> {
        self.mask_dirty.as_range()
    }

    /// Mark both serialization buffers as consumed (e.g. after uploading
    /// them as graph inputs).
    pub fn clear_dirty(&mut self) {
        self.table_dirty.clear();
        self.mask_dirty.clear();
    }

    /// Compatibility wrapper: owned copy of [`SeqCache::block_table`],
    /// additionally allowing `nb > capacity_blocks()` pads.
    pub fn block_table_i32(&self, nb: usize) -> Vec<i32> {
        if nb <= self.table.len() {
            return self.block_table(nb).to_vec();
        }
        let mut t = self.table.clone();
        t.resize(nb, 0);
        t
    }

    /// Compatibility wrapper: owned copy of [`SeqCache::valid_mask`],
    /// additionally allowing `nb > capacity_blocks()` pads.
    pub fn valid_mask_f32(&self, nb: usize) -> Vec<f32> {
        if nb <= self.bucket_blocks {
            return self.valid_mask(nb).to_vec();
        }
        let mut m = self.mask.clone();
        m.resize(nb * self.block_size, 0.0);
        m
    }

    /// From-scratch O(NB) table rebuild — the pre-incremental code path,
    /// kept as the property-test oracle and the micro-bench baseline.
    pub fn rebuild_block_table(&self, nb: usize) -> Vec<i32> {
        assert!(self.blocks.len() <= nb, "table exceeds bucket");
        let mut t: Vec<i32> = self.blocks.iter().map(|b| b.phys as i32).collect();
        t.resize(nb, 0);
        t
    }

    /// From-scratch O(NB * B) mask rebuild — the pre-incremental code path,
    /// kept as the property-test oracle and the micro-bench baseline.
    pub fn rebuild_valid_mask(&self, nb: usize) -> Vec<f32> {
        assert!(self.blocks.len() <= nb, "mask exceeds bucket");
        let mut m = vec![0.0f32; nb * self.block_size];
        for (bi, blk) in self.blocks.iter().enumerate() {
            blk.write_mask_into(&mut m[bi * self.block_size..(bi + 1) * self.block_size]);
        }
        m
    }

    /// Fill `out` with (logical block idx, offset, position, scores) of
    /// every live token, oldest-first — the view token-level policies scan.
    /// Clears and reuses `out` so steady-state callers allocate nothing.
    pub fn collect_live_tokens(&self, out: &mut Vec<(usize, usize, u32, [f32; 3])>) {
        out.clear();
        for (bi, blk) in self.blocks.iter().enumerate() {
            for (off, pos, sc) in blk.live_tokens() {
                out.push((bi, off, pos, sc));
            }
        }
    }

    /// Owned live-token list (allocating convenience over
    /// [`SeqCache::collect_live_tokens`]).
    pub fn live_token_list(&self) -> Vec<(usize, usize, u32, [f32; 3])> {
        let mut out = Vec::with_capacity(self.live_tokens());
        self.collect_live_tokens(&mut out);
        out
    }

    // -- swap-to-host --------------------------------------------------------

    /// Capture the full host-side cache state for swap-to-host preemption.
    /// Pure copy: the cache keeps running (or is dropped by the caller,
    /// returning its arena pages) and the snapshot stays valid either way.
    pub fn snapshot(&self) -> KvSnapshot {
        KvSnapshot {
            block_size: self.block_size,
            bucket_blocks: self.bucket_blocks,
            blocks: self.blocks.clone(),
            local_free: self.local_free.clone(),
            next_position: self.next_position,
            partial_count: self.partial_count,
            table: self.table.clone(),
            mask: self.mask.clone(),
            stats: self.stats.clone(),
        }
    }

    /// Rebuild a cache from a snapshot, claiming fresh pages from `arena`
    /// (one per snapshotted block). Local device slots are preserved, so
    /// the restored block table / validity mask are bit-identical to the
    /// suspended cache's; both are marked fully dirty because a restored
    /// sequence's device buffers need a complete upload.
    ///
    /// Fails with [`BlockAlloc::ArenaDry`] — claiming nothing — when the
    /// arena cannot hold the snapshot right now; the caller retries later
    /// or falls back to recompute.
    pub fn restore_from(snap: &KvSnapshot, arena: &BlockManager) -> Result<SeqCache, BlockAlloc> {
        let seq = arena.register();
        let mut blocks = snap.blocks.clone();
        // A snapshot restores onto PRIVATE copies: blocks the suspended
        // sequence mapped from the prefix index come back as fresh
        // unpublished pages (the published originals live on with, and
        // are freed by, their surviving holders). Pinned by the swap
        // bit-identity tests — sharing is arena accounting only, so
        // the restored serialization cannot tell the difference. All
        // pages are claimed under one lock; failure claims nothing.
        let Some(pages) = arena.alloc_many(seq, blocks.len()) else {
            arena.unregister(seq);
            return Err(BlockAlloc::ArenaDry);
        };
        for (blk, page) in blocks.iter_mut().zip(pages) {
            blk.prefix_tracked = false;
            blk.arena_slot = page;
        }
        Ok(SeqCache {
            block_size: snap.block_size,
            mgr: arena.clone(),
            seq,
            bucket_blocks: snap.bucket_blocks,
            owns_arena: false,
            local_free: snap.local_free.clone(),
            blocks,
            next_position: snap.next_position,
            partial_count: snap.partial_count,
            table: snap.table.clone(),
            mask: snap.mask.clone(),
            table_dirty: DirtyRange::full(snap.table.len()),
            mask_dirty: DirtyRange::full(snap.mask.len()),
            stats: snap.stats.clone(),
        })
    }

    /// Consistency invariants — called by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        // device slots unique within the bucket; arena pages unique and
        // within the arena
        let mut seen = std::collections::HashSet::new();
        let mut seen_arena = std::collections::HashSet::new();
        for b in &self.blocks {
            if b.phys >= self.bucket_blocks {
                return Err(format!("phys {} out of bucket", b.phys));
            }
            if !seen.insert(b.phys) {
                return Err(format!("duplicate phys slot {}", b.phys));
            }
            if b.arena_slot >= self.mgr.capacity() {
                return Err(format!("arena slot {} out of arena", b.arena_slot));
            }
            if !seen_arena.insert(b.arena_slot) {
                return Err(format!("duplicate arena slot {}", b.arena_slot));
            }
            if b.fill > self.block_size {
                return Err("overfull block".into());
            }
            // prefix-cache consistency: only full, append-closed blocks are
            // ever shareable, and a block outside the index must be the
            // sole holder of its page (nobody can acquire an unpublished
            // page, and CoW/unpublish clear the flag together)
            if b.prefix_tracked {
                if b.fill != self.block_size {
                    return Err("prefix-tracked block not full".into());
                }
                if self.mgr.refcount(b.arena_slot) == 0 {
                    return Err(format!("prefix-tracked block on free page {}", b.arena_slot));
                }
            } else if self.mgr.refcount(b.arena_slot) != 1 {
                return Err(format!(
                    "untracked block shares page {} (refcount {})",
                    b.arena_slot,
                    self.mgr.refcount(b.arena_slot)
                ));
            }
        }
        // local slot free list accounts for every bucket slot exactly once
        if self.local_free.len() + self.blocks.len() != self.bucket_blocks {
            return Err(format!(
                "local free {} + blocks {} != bucket {}",
                self.local_free.len(),
                self.blocks.len(),
                self.bucket_blocks
            ));
        }
        // only the last block may be partially filled
        for (i, b) in self.blocks.iter().enumerate() {
            if i + 1 != self.blocks.len() && b.fill != self.block_size {
                return Err(format!("non-terminal block {i} not full"));
            }
        }
        // arena ownership accounting adds up
        if self.mgr.owned_by(self.seq) != self.blocks.len() {
            return Err(format!(
                "arena owned {} != live blocks {}",
                self.mgr.owned_by(self.seq),
                self.blocks.len()
            ));
        }
        // incremental fragmentation counter matches a rescan
        let scanned_partial = self.blocks.iter().filter(|b| b.is_partial()).count();
        if self.partial_count != scanned_partial {
            return Err(format!(
                "partial counter {} != scanned {scanned_partial}",
                self.partial_count
            ));
        }
        // incremental serialization buffers are sized to the bucket and
        // bit-identical to a from-scratch rebuild
        let cap = self.bucket_blocks;
        if self.table.len() != cap {
            return Err(format!("table len {} != capacity {cap}", self.table.len()));
        }
        if self.mask.len() != cap * self.block_size {
            return Err(format!(
                "mask len {} != capacity * B = {}",
                self.mask.len(),
                cap * self.block_size
            ));
        }
        if self.table != self.rebuild_block_table(cap) {
            return Err("incremental block table drifted from rebuild".into());
        }
        if self.mask != self.rebuild_valid_mask(cap) {
            return Err("incremental valid mask drifted from rebuild".into());
        }
        Ok(())
    }
}

/// Retiring or preempting a sequence is just dropping its cache: every
/// claim it still holds returns to the shared arena — private pages free
/// immediately, shared pages merely drop one reference and live on for
/// their other holders (so evicting-from-running sequence A can never
/// corrupt sequence B's view of a shared prefix). Blocks are released
/// explicitly (O(blocks held)) so `unregister` never needs its
/// O(arena-capacity) holder-scan fallback on the hot retire/preempt path.
impl Drop for SeqCache {
    fn drop(&mut self) {
        let slots: Vec<usize> = self.blocks.drain(..).map(|b| b.arena_slot).collect();
        self.mgr.release_many(self.seq, &slots);
        self.mgr.unregister(self.seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn sc(x: f32) -> [f32; 3] {
        [x, x, x]
    }

    #[test]
    fn prefix_hash_seed_binds_the_channel_layout() {
        let entries: Vec<(u32, [f32; 3])> = (0..8).map(|i| (i, sc(i as f32))).collect();
        let keys: Vec<u64> = (0..8).map(|i| 0x1000 + i as u64).collect();
        let base = prefix_block_hashes(4, &entries, &keys);
        assert_eq!(base.len(), 2);
        assert_eq!(
            base,
            prefix_block_hashes_with_layout(SCORE_LAYOUT_V1, 4, &entries, &keys),
            "the default wrapper IS the v1 layout"
        );
        // the same entries packed under a different channel count hash to
        // a disjoint chain (no cross-layout prefix-index aliasing)...
        let wider = ChannelLayout { channels: SCORE_LAYOUT_V1.channels + 1, version: 1 };
        let w = prefix_block_hashes_with_layout(wider, 4, &entries, &keys);
        assert!(base.iter().zip(&w).all(|(a, b)| a != b), "layouts must never alias");
        // ...and so does a semantics version bump at the SAME count
        let v2 = ChannelLayout { channels: SCORE_LAYOUT_V1.channels, version: 2 };
        let v = prefix_block_hashes_with_layout(v2, 4, &entries, &keys);
        assert!(base.iter().zip(&v).all(|(a, b)| a != b));
        assert!(w.iter().zip(&v).all(|(a, b)| a != b));
        assert_ne!(SCORE_LAYOUT_V1.tag(), wider.tag());
        assert_ne!(SCORE_LAYOUT_V1.tag(), v2.tag());
    }

    #[test]
    fn prefill_then_decode_layout() {
        let mut c = SeqCache::new(4, 8);
        let toks: Vec<(u32, [f32; 3])> = (0..10).map(|i| (i, sc(i as f32))).collect();
        c.load_prefill(&toks, 10);
        assert_eq!(c.n_blocks(), 3);
        assert_eq!(c.live_tokens(), 10);
        assert_eq!(c.block_table_i32(8), vec![0, 1, 2, 0, 0, 0, 0, 0]);
        assert_eq!(c.block_table(8), &[0, 1, 2, 0, 0, 0, 0, 0]);
        let m = c.valid_mask_f32(8);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 10);
        assert_eq!(&m[..10], &[1.0; 10]);
        assert_eq!(c.valid_mask(8), m.as_slice());
        // next write goes to block 2 offset 2 -> phys 2*4+2
        assert_eq!(c.peek_write_slot(), Some(10));
        c.check_invariants().unwrap();
    }

    #[test]
    fn prefill_after_eviction_keeps_original_positions() {
        let mut c = SeqCache::new(4, 8);
        // prompt of 16 tokens, evicted down to 8 (every other token)
        let toks: Vec<(u32, [f32; 3])> = (0..16).step_by(2).map(|i| (i, sc(0.0))).collect();
        c.load_prefill(&toks, 16);
        assert_eq!(c.next_position(), 16, "decode must continue at position 16");
        assert_eq!(c.live_tokens(), 8);
    }

    #[test]
    fn append_path() {
        let mut c = SeqCache::new(4, 4);
        assert!(c.ensure_block());
        assert_eq!(c.peek_write_slot(), Some(0));
        c.append(sc(1.0));
        assert_eq!(c.live_tokens(), 1);
        for _ in 0..3 {
            assert!(c.ensure_block());
            c.append(sc(1.0));
        }
        assert!(c.last_block_full());
        assert!(c.ensure_block());
        assert_eq!(c.peek_write_slot(), Some(4));
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_block_frees_slot_and_shifts_table() {
        let mut c = SeqCache::new(2, 4);
        let toks: Vec<(u32, [f32; 3])> = (0..6).map(|i| (i, sc(i as f32))).collect();
        c.load_prefill(&toks, 6);
        assert_eq!(c.n_blocks(), 3);
        c.evict_block(1); // drop tokens 2,3
        assert_eq!(c.n_blocks(), 2);
        assert_eq!(c.block_table_i32(4), vec![0, 2, 0, 0]);
        assert_eq!(c.live_tokens(), 4);
        // freed slot 1 is reused next
        assert!(c.ensure_block());
        assert_eq!(c.blocks().last().unwrap().phys, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn kill_token_drains_then_frees_block() {
        let mut c = SeqCache::new(2, 4);
        c.load_prefill(&(0..4).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 4);
        assert_eq!(c.n_blocks(), 2);
        c.kill_token(0, 0);
        assert_eq!(c.n_blocks(), 2, "partially dead block stays allocated");
        assert_eq!(c.partial_blocks(), 1);
        assert!(c.occupancy() < 1.0);
        c.kill_token(0, 1);
        assert_eq!(c.n_blocks(), 1, "drained block is freed");
        assert_eq!(c.stats.blocks_evicted, 1);
        assert!(c.stats.peak_partial_blocks >= 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn valid_mask_reflects_holes() {
        let mut c = SeqCache::new(4, 2);
        c.load_prefill(&(0..8).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 8);
        c.kill_token(1, 2);
        let m = c.valid_mask_f32(2);
        assert_eq!(m[6], 0.0);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 7);
        assert_eq!(c.valid_mask(2), m.as_slice());
    }

    #[test]
    fn grow_extends_pool() {
        let mut c = SeqCache::new(2, 2);
        c.load_prefill(&(0..4).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 4);
        assert!(c.needs_grow());
        c.grow(4);
        assert!(!c.needs_grow());
        assert!(c.ensure_block());
        c.append(sc(0.0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn oversized_pad_still_supported_by_wrappers() {
        // Pre-incremental callers could pad past the pool capacity; the
        // compatibility wrappers keep that working.
        let mut c = SeqCache::new(2, 2);
        c.load_prefill(&(0..3).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 3);
        assert_eq!(c.block_table_i32(5), vec![0, 1, 0, 0, 0]);
        assert_eq!(c.valid_mask_f32(5).len(), 10);
        assert_eq!(c.valid_mask_f32(5)[..3], [1.0, 1.0, 1.0]);
    }

    #[test]
    fn with_incoming_mask_stages_and_restores() {
        let mut c = SeqCache::new(4, 4);
        c.load_prefill(&(0..5).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 5);
        // next append lands at logical slot 5 (block 1, offset 1)
        assert!(c.ensure_block());
        let seen = c.with_incoming_mask(4, 5, |m| (m.len(), m[5], m[4]));
        assert_eq!(seen, (16, 1.0, 1.0), "staged view shows the incoming slot live");
        assert_eq!(c.valid_mask(4)[5], 0.0, "committed buffer restored");
        c.check_invariants().unwrap();
        // the staged view must not disturb a previously killed slot either
        c.kill_token(0, 2);
        let v = c.with_incoming_mask(4, 5, |m| m[2]);
        assert_eq!(v, 0.0);
        assert_eq!(c.valid_mask(4)[2], 0.0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn dirty_ranges_track_mutations() {
        let mut c = SeqCache::new(4, 8);
        // fresh cache: everything dirty (first upload sends it all)
        assert_eq!(c.table_dirty(), Some(0..8));
        assert_eq!(c.mask_dirty(), Some(0..32));
        c.load_prefill(&(0..10).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 10);
        c.clear_dirty();
        assert_eq!(c.table_dirty(), None);
        assert_eq!(c.mask_dirty(), None);

        // append into block 2 (offsets 2..) -> one mask slot dirty
        assert!(c.ensure_block());
        c.append(sc(0.0));
        assert_eq!(c.table_dirty(), None, "no new block, table untouched");
        assert_eq!(c.mask_dirty(), Some(10..11));
        c.clear_dirty();

        // kill token at block 0, off 1 -> slot 1 dirty
        c.kill_token(0, 1);
        assert_eq!(c.mask_dirty(), Some(1..2));
        c.clear_dirty();

        // evict block 1 of 3 -> table suffix 1..3 and mask 4..12 dirty
        c.evict_block(1);
        assert_eq!(c.table_dirty(), Some(1..3));
        assert_eq!(c.mask_dirty(), Some(4..12));
        c.clear_dirty();

        // grow -> new tail regions dirty
        c.grow(10);
        assert_eq!(c.table_dirty(), Some(8..10));
        assert_eq!(c.mask_dirty(), Some(32..40));
    }

    #[test]
    fn shared_arena_two_tenants_account_globally() {
        use crate::kvcache::block_manager::BlockManager;
        let arena = BlockManager::new(4);
        let mut a = SeqCache::new_shared(2, 4, &arena);
        let mut b = SeqCache::new_shared(2, 4, &arena);
        a.load_prefill(&(0..4).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 4);
        b.load_prefill(&(0..2).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 2);
        assert_eq!(arena.used(), 3);
        assert!(b.ensure_block(), "4th arena block");
        assert_eq!(arena.free_count(), 0);
        assert_eq!(a.try_ensure_block(), BlockAlloc::ArenaDry);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
        drop(b);
        assert_eq!(arena.used(), 2, "dropping a tenant returns its blocks");
        assert_eq!(a.try_ensure_block(), BlockAlloc::Ready);
        a.check_invariants().unwrap();
    }

    #[test]
    fn bucket_full_and_arena_dry_are_distinct_failures() {
        use crate::kvcache::block_manager::BlockManager;
        let arena = BlockManager::new(8);
        let mut c = SeqCache::new_shared(2, 1, &arena); // one-block bucket
        c.load_prefill(&[(0, sc(0.0)), (1, sc(0.0))], 2);
        assert_eq!(c.try_ensure_block(), BlockAlloc::BucketFull);
        c.grow(2); // bucket growth, arena untouched
        assert_eq!(arena.capacity(), 8);
        assert_eq!(c.try_ensure_block(), BlockAlloc::Ready);
        assert_eq!(arena.used(), 2);
        c.check_invariants().unwrap();
    }

    #[test]
    fn try_load_prefill_reports_arena_dry_and_drop_reclaims() {
        use crate::kvcache::block_manager::BlockManager;
        let arena = BlockManager::new(1);
        let mut c = SeqCache::new_shared(2, 4, &arena);
        let toks: Vec<(u32, [f32; 3])> = (0..4).map(|i| (i, sc(0.0))).collect();
        assert_eq!(c.try_load_prefill(&toks, 4), Err(BlockAlloc::ArenaDry));
        drop(c);
        assert_eq!(arena.used(), 0, "partially loaded blocks returned on drop");
    }

    /// The serialization-relevant state two caches must agree on for the
    /// decode graph (and the policies) to behave identically.
    fn assert_same_state(a: &SeqCache, b: &SeqCache) {
        let nb = a.capacity_blocks();
        assert_eq!(b.capacity_blocks(), nb);
        assert_eq!(a.block_table(nb), b.block_table(nb));
        assert_eq!(a.valid_mask(nb), b.valid_mask(nb));
        assert_eq!(a.live_token_list(), b.live_token_list());
        assert_eq!(a.next_position(), b.next_position());
        assert_eq!(a.partial_blocks(), b.partial_blocks());
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn snapshot_restore_roundtrip_preserves_state_and_arena_accounting() {
        let arena = BlockManager::new(32);
        let mut c = SeqCache::new_shared(4, 8, &arena);
        c.load_prefill(&(0..14).map(|i| (i, sc(i as f32))).collect::<Vec<_>>(), 14);
        c.kill_token(1, 2); // fragment a page so the mask is non-trivial
        c.evict_block(0); // shift the table so phys != logical
        assert!(c.ensure_block());
        c.append(sc(9.0));
        let snap = c.snapshot();
        assert_eq!(snap.n_blocks(), c.n_blocks());
        assert!(snap.host_bytes() > 0);

        let used_before = arena.used();
        let r = SeqCache::restore_from(&snap, &arena).expect("arena has room");
        assert_eq!(arena.used(), used_before + snap.n_blocks());
        r.check_invariants().unwrap();
        assert_same_state(&c, &r);
        // restored buffers need a full device upload
        assert_eq!(r.table_dirty(), Some(0..r.capacity_blocks()));
        drop(r);
        assert_eq!(arena.used(), used_before, "restored blocks return on drop");
        // the original cache is untouched by snapshotting
        c.check_invariants().unwrap();
    }

    #[test]
    fn restore_from_dry_arena_claims_nothing() {
        let arena = BlockManager::new(8);
        let mut c = SeqCache::new_shared(4, 8, &arena);
        c.load_prefill(&(0..20).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 20);
        let snap = c.snapshot();
        // 5 blocks held, 3 free: a second copy cannot fit
        assert_eq!(
            SeqCache::restore_from(&snap, &arena).err(),
            Some(BlockAlloc::ArenaDry)
        );
        assert_eq!(arena.used(), 5, "failed restore leaks no blocks");
        assert_eq!(arena.stats().sequences, 1, "failed restore leaks no seq id");
        // after the original drops (preemption), the restore succeeds
        drop(c);
        let r = SeqCache::restore_from(&snap, &arena).expect("now it fits");
        r.check_invariants().unwrap();
        assert_eq!(r.live_tokens(), 20);
    }

    #[test]
    fn restored_cache_continues_decoding_identically() {
        let arena = BlockManager::new(64);
        let mut c = SeqCache::new_shared(4, 12, &arena);
        c.load_prefill(&(0..10).map(|i| (i, sc(i as f32))).collect::<Vec<_>>(), 10);
        let snap = c.snapshot();
        let mut r = SeqCache::restore_from(&snap, &arena).unwrap();
        // identical mutation streams must keep the two caches identical
        for step in 0..20u32 {
            for cache in [&mut c, &mut r] {
                assert!(cache.ensure_block());
                cache.append(sc(step as f32));
                if step % 5 == 4 {
                    cache.kill_token(1, (step as usize / 5) % 4);
                }
            }
            assert_same_state(&c, &r);
        }
        c.check_invariants().unwrap();
        r.check_invariants().unwrap();
    }

    fn keys_for(n: u32) -> Vec<u64> {
        (0..n as u64).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15) ^ 0xbeef).collect()
    }

    #[test]
    fn cached_load_matches_uncached_serialization_bit_for_bit() {
        let arena = BlockManager::new(64);
        let toks: Vec<(u32, [f32; 3])> = (0..14).map(|i| (i, sc(i as f32))).collect();
        let keys = keys_for(14);
        let mut plain = SeqCache::new_shared(4, 8, &arena);
        plain.load_prefill(&toks, 14);
        let mut cached = SeqCache::new_shared(4, 8, &arena);
        assert_eq!(
            cached.try_load_prefill_cached(&toks, &keys, 14),
            Ok(0),
            "no publisher yet: zero hits"
        );
        assert_eq!(cached.block_table(8), plain.block_table(8));
        assert_eq!(cached.valid_mask(8), plain.valid_mask(8));
        assert_eq!(cached.live_token_list(), plain.live_token_list());
        assert_eq!(cached.next_position(), plain.next_position());
        cached.check_invariants().unwrap();
        // the full blocks are now published: a third tenant maps all three
        // by reference and only materializes the 2-token tail
        let used_before = arena.used();
        let mut third = SeqCache::new_shared(4, 8, &arena);
        assert_eq!(third.try_load_prefill_cached(&toks, &keys, 14), Ok(3));
        assert_eq!(third.stats.prefix_hit_blocks, 3);
        assert_eq!(arena.used(), used_before + 1, "only the tail block is new");
        assert_eq!(third.block_table(8), plain.block_table(8));
        assert_eq!(third.valid_mask(8), plain.valid_mask(8));
        assert_eq!(third.live_token_list(), plain.live_token_list());
        third.check_invariants().unwrap();
        cached.check_invariants().unwrap();
    }

    #[test]
    fn kill_on_shared_page_copies_on_write() {
        let arena = BlockManager::new(16);
        let toks: Vec<(u32, [f32; 3])> = (0..8).map(|i| (i, sc(i as f32))).collect();
        let keys = keys_for(8);
        let mut a = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(a.try_load_prefill_cached(&toks, &keys, 8), Ok(0));
        let mut b = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(b.try_load_prefill_cached(&toks, &keys, 8), Ok(2));
        assert_eq!(arena.used(), 2, "both prompts live on two physical pages");
        let shared = b.blocks()[0].arena_slot;
        assert_eq!(shared, a.blocks()[0].arena_slot);
        let a_table = a.block_table(4).to_vec();
        let a_mask = a.valid_mask(4).to_vec();
        b.kill_token(0, 1); // in-place write: copy-on-write fires first
        assert_eq!(b.stats.cow_copies, 1);
        assert_ne!(b.blocks()[0].arena_slot, shared, "writer moved to a private page");
        assert_eq!(arena.refcount(shared), 1, "a is the sole holder again");
        assert_eq!(arena.used(), 3);
        assert_eq!(a.block_table(4), a_table.as_slice(), "a's view is untouched");
        assert_eq!(a.valid_mask(4), a_mask.as_slice());
        assert!(a.blocks()[0].is_live(1));
        assert!(!b.blocks()[0].is_live(1));
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
    }

    #[test]
    fn evicting_shared_blocks_releases_by_refcount() {
        let arena = BlockManager::new(16);
        let toks: Vec<(u32, [f32; 3])> = (0..8).map(|i| (i, sc(0.5))).collect();
        let keys = keys_for(8);
        let mut a = SeqCache::new_shared(4, 4, &arena);
        a.try_load_prefill_cached(&toks, &keys, 8).unwrap();
        let mut b = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(b.try_load_prefill_cached(&toks, &keys, 8), Ok(2));
        let s0 = a.blocks()[0].arena_slot;
        b.evict_block(0);
        assert_eq!(arena.used(), 2, "a still holds both pages");
        assert_eq!(arena.refcount(s0), 1);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
        a.evict_block(0);
        assert_eq!(arena.used(), 1, "the last holder frees the page");
        assert_eq!(arena.refcount(s0), 0);
        // the freed page left the index: a fresh identical prompt misses on
        // block 0 (the chain stops at the first miss) and re-materializes
        let mut c = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(c.try_load_prefill_cached(&toks, &keys, 8), Ok(0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn kill_on_sole_holder_published_page_unpublishes_without_copy() {
        let arena = BlockManager::new(16);
        let toks: Vec<(u32, [f32; 3])> = (0..8).map(|i| (i, sc(1.0))).collect();
        let keys = keys_for(8);
        let mut a = SeqCache::new_shared(4, 4, &arena);
        a.try_load_prefill_cached(&toks, &keys, 8).unwrap();
        assert!(arena.is_published(a.blocks()[0].arena_slot));
        a.kill_token(0, 0);
        assert_eq!(a.stats.cow_copies, 0, "sole holder writes in place");
        assert!(
            !arena.is_published(a.blocks()[0].arena_slot),
            "mutated content must leave the index"
        );
        a.check_invariants().unwrap();
        // block 1 is still published, but the chain breaks at block 0
        let mut b = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(b.try_load_prefill_cached(&toks, &keys, 8), Ok(0));
    }

    #[test]
    fn unshare_shared_blocks_copies_only_shared_pages() {
        let arena = BlockManager::new(16);
        let toks: Vec<(u32, [f32; 3])> = (0..8).map(|i| (i, sc(2.0))).collect();
        let keys = keys_for(8);
        let mut a = SeqCache::new_shared(4, 4, &arena);
        a.try_load_prefill_cached(&toks, &keys, 8).unwrap();
        assert_eq!(a.unshare_shared_blocks(), Ok(0), "no sharers yet: nothing to copy");
        assert!(
            arena.is_published(a.blocks()[0].arena_slot),
            "sole-holder pages stay published until actually written"
        );
        let mut b = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(b.try_load_prefill_cached(&toks, &keys, 8), Ok(2));
        assert_eq!(b.unshare_shared_blocks(), Ok(2), "both hit pages get private copies");
        assert_eq!(b.stats.cow_copies, 2);
        assert_eq!(b.unshare_shared_blocks(), Ok(0), "idempotent once private");
        assert_eq!(arena.used(), 4);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
    }

    #[test]
    fn cow_reports_arena_dry_without_side_effects() {
        let arena = BlockManager::new(2);
        let toks: Vec<(u32, [f32; 3])> = (0..8).map(|i| (i, sc(3.0))).collect();
        let keys = keys_for(8);
        let mut a = SeqCache::new_shared(4, 4, &arena);
        a.try_load_prefill_cached(&toks, &keys, 8).unwrap();
        let mut b = SeqCache::new_shared(4, 4, &arena);
        assert_eq!(b.try_load_prefill_cached(&toks, &keys, 8), Ok(2));
        assert_eq!(arena.free_count(), 0, "sharing filled nothing extra");
        assert_eq!(b.try_kill_token(0, 0), Err(BlockAlloc::ArenaDry));
        assert!(b.blocks()[0].is_live(0), "failed copy-on-write kills nothing");
        assert_eq!(b.stats.cow_copies, 0);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
        // once the co-holder leaves, b is the sole holder: the kill
        // unpublishes in place and needs no copy at all
        drop(a);
        assert_eq!(b.try_kill_token(0, 0), Ok(()));
        assert_eq!(b.stats.cow_copies, 0);
        b.check_invariants().unwrap();
    }

    #[test]
    fn property_cached_load_is_serialization_identical_to_uncached() {
        propcheck::quick("prefill-cached-identity", |rng| {
            let bs = *rng.choose(&[2usize, 4, 8]);
            let cap = 4 + rng.usize_below(8);
            let n = 1 + rng.usize_below(cap * bs - 1);
            let toks: Vec<(u32, [f32; 3])> = (0..n as u32)
                .map(|i| (i, [rng.f32(), rng.f32(), rng.f32()]))
                .collect();
            let keys: Vec<u64> = (0..n as u64).map(|i| mix64(i ^ 0x5ca1ab1e)).collect();
            let arena = BlockManager::new(4 * cap);
            let mut plain = SeqCache::new_shared(bs, cap, &arena);
            plain
                .try_load_prefill(&toks, n as u32)
                .map_err(|e| format!("uncached load failed: {e:?}"))?;
            // publisher (0 hits), then a borrower (full-block hits)
            let mut keep_alive = Vec::new();
            for expect_hits in [0usize, n / bs] {
                let mut c = SeqCache::new_shared(bs, cap, &arena);
                let hits = c
                    .try_load_prefill_cached(&toks, &keys, n as u32)
                    .map_err(|e| format!("cached load failed: {e:?}"))?;
                if hits != expect_hits {
                    return Err(format!("hits {hits} != expected {expect_hits}"));
                }
                if c.block_table(cap) != plain.block_table(cap) {
                    return Err("block table drifted from the uncached path".into());
                }
                if c.valid_mask(cap) != plain.valid_mask(cap) {
                    return Err("validity mask drifted from the uncached path".into());
                }
                if c.live_token_list() != plain.live_token_list() {
                    return Err("live-token view drifted from the uncached path".into());
                }
                c.check_invariants()?;
                keep_alive.push(c); // keep the claims so the next round hits
            }
            Ok(())
        });
    }

    #[test]
    fn property_random_op_sequences_keep_invariants() {
        propcheck::quick("seqcache-invariants", |rng| {
            let bs = *rng.choose(&[2usize, 4, 8, 16]);
            let cap = 4 + rng.usize_below(12);
            let mut c = SeqCache::new(bs, cap);
            let pre = rng.usize_below(cap * bs / 2) + 1;
            c.load_prefill(
                &(0..pre as u32).map(|i| (i, [rng.f32(), rng.f32(), rng.f32()])).collect::<Vec<_>>(),
                pre as u32,
            );
            for _ in 0..200 {
                match rng.below(10) {
                    0..=5 => {
                        if c.ensure_block() {
                            c.append([rng.f32(), rng.f32(), rng.f32()]);
                        } else if c.capacity_blocks() < 64 {
                            c.grow(c.capacity_blocks() + 2);
                        }
                    }
                    6..=7 => {
                        if c.n_blocks() > 1 {
                            let idx = c.n_blocks() - 1 - rng.usize_below(c.n_blocks() - 1).max(0);
                            // never evict the newest block (policy convention)
                            let idx = idx.min(c.n_blocks() - 2);
                            c.evict_block(idx);
                        }
                    }
                    _ => {
                        let live = c.live_token_list();
                        if live.len() > 1 {
                            let (bi, off, _, _) = live[rng.usize_below(live.len())];
                            c.kill_token(bi, off);
                        }
                    }
                }
                c.check_invariants()?;
                // serialization shapes must always be consistent
                let nb = c.capacity_blocks();
                let t = c.block_table(nb);
                let m = c.valid_mask(nb);
                if t.len() != nb || m.len() != nb * bs {
                    return Err("bad serialization lengths".into());
                }
                let live_in_mask = m.iter().filter(|&&x| x == 1.0).count();
                if live_in_mask != c.live_tokens() {
                    return Err(format!(
                        "mask live {} != tracked {}",
                        live_in_mask,
                        c.live_tokens()
                    ));
                }
            }
            Ok(())
        });
    }
}
