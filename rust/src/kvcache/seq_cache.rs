//! Per-sequence cache state: block table + validity + scores.
//!
//! This is the host-side single source of truth for what the decode graph
//! sees. Every mutation (append, block eviction, token kill) updates the
//! metadata the runtime serializes into graph inputs:
//!   * `block_table_i32()` — logical->physical, padded to the bucket size;
//!   * `valid_mask_f32()`  — [NB * B] 1.0/0.0 in logical order;
//!   * `next_write_slot()` — physical flat index for the incoming token.

use super::block::{Block, BlockPool};
use super::stats::CacheStats;

/// Number of importance channels carried per token
/// (0 = V/K ratio, 1 = key L2 norm, 2 = KeyDiff cosine).
pub const SCORE_CHANNELS: usize = 3;

#[derive(Debug, Clone)]
pub struct SeqCache {
    block_size: usize,
    pool: BlockPool,
    /// Logical block order (oldest first). `blocks[i].phys` is the slot.
    blocks: Vec<Block>,
    /// Highest sequence position written so far + 1 (monotonic; survives
    /// eviction — RoPE positions are original positions).
    next_position: u32,
    pub stats: CacheStats,
}

impl SeqCache {
    /// `capacity_blocks` = physical slots in the current device bucket.
    pub fn new(block_size: usize, capacity_blocks: usize) -> Self {
        SeqCache {
            block_size,
            pool: BlockPool::new(capacity_blocks),
            blocks: Vec::new(),
            next_position: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn capacity_blocks(&self) -> usize {
        self.pool.capacity()
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_count()
    }

    /// Live (attention-visible) tokens.
    pub fn live_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.live_count()).sum()
    }

    /// Tokens ever written and not yet block-evicted (incl. dead ones).
    pub fn held_tokens(&self) -> usize {
        self.blocks.iter().map(|b| b.fill).sum()
    }

    /// Allocated-but-fragmented pages (paper Limitation 1 metric).
    pub fn partial_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_partial()).count()
    }

    /// live / allocated-slot tokens; 1.0 = perfectly packed.
    pub fn occupancy(&self) -> f64 {
        let alloc = self.blocks.len() * self.block_size;
        if alloc == 0 {
            return 1.0;
        }
        self.live_tokens() as f64 / alloc as f64
    }

    pub fn next_position(&self) -> u32 {
        self.next_position
    }

    /// True when the newest block is full (or none exists) — i.e. the next
    /// append needs a fresh block. This is the paper's decode-phase
    /// eviction trigger (`L % B == 0`).
    pub fn last_block_full(&self) -> bool {
        self.blocks.last().map_or(true, |b| b.fill == self.block_size)
    }

    /// Whether an append right now would need an allocation that the pool
    /// cannot satisfy (runtime must grow the bucket or scheduler preempt).
    pub fn needs_grow(&self) -> bool {
        self.last_block_full() && self.pool.free_count() == 0
    }

    // -- append path --------------------------------------------------------

    /// Physical flat slot (block * B + offset) where the NEXT token will be
    /// written. Allocates nothing; errors if a new block is needed but the
    /// pool is empty.
    pub fn peek_write_slot(&self) -> Option<usize> {
        if self.last_block_full() {
            None // needs alloc first; use ensure_block()
        } else {
            let b = self.blocks.last().unwrap();
            Some(b.phys * self.block_size + b.fill)
        }
    }

    /// Make sure a block with a free slot exists. Returns false if the pool
    /// is exhausted (caller grows/preempts).
    pub fn ensure_block(&mut self) -> bool {
        if !self.last_block_full() {
            return true;
        }
        match self.pool.alloc() {
            Some(phys) => {
                self.blocks.push(Block::new(phys, self.block_size));
                self.stats.blocks_allocated += 1;
                self.stats.table_updates += 1;
                true
            }
            None => false,
        }
    }

    /// Record the token the decode step just wrote at `peek_write_slot`.
    pub fn append(&mut self, scores: [f32; 3]) {
        assert!(!self.last_block_full(), "append without ensure_block()");
        let pos = self.next_position;
        self.blocks.last_mut().unwrap().push(pos, scores);
        self.next_position += 1;
        self.stats.tokens_written += 1;
    }

    /// Bulk-load a prefilled, already-evicted prompt: `tokens[i]` is
    /// (original_position, [3]scores), laid out contiguously from physical
    /// slot 0 in logical order (matching the runtime's host-side pack).
    pub fn load_prefill(&mut self, tokens: &[(u32, [f32; 3])], total_prompt_len: u32) {
        assert!(self.blocks.is_empty(), "load_prefill on non-empty cache");
        for (pos, sc) in tokens {
            if self.last_block_full() {
                let phys = self.pool.alloc().expect("prefill exceeds pool");
                self.blocks.push(Block::new(phys, self.block_size));
                self.stats.blocks_allocated += 1;
            }
            self.blocks.last_mut().unwrap().push(*pos, *sc);
        }
        self.stats.tokens_written += tokens.len() as u64;
        self.stats.table_updates += 1;
        self.next_position = total_prompt_len;
    }

    // -- eviction primitives -------------------------------------------------

    /// Structured eviction: drop logical block `idx` entirely. O(blocks)
    /// table shift, zero device-data movement. Frees the physical slot.
    pub fn evict_block(&mut self, idx: usize) {
        let blk = self.blocks.remove(idx);
        self.stats.tokens_evicted += blk.live_count() as u64;
        self.stats.blocks_evicted += 1;
        self.stats.table_updates += 1;
        self.pool.release(blk.phys);
    }

    /// Unstructured eviction: kill one token at (logical block, offset).
    /// Frees the block only once every token in it is dead.
    pub fn kill_token(&mut self, block_idx: usize, off: usize) {
        let killed = self.blocks[block_idx].kill(off);
        assert!(killed, "killing dead token ({block_idx},{off})");
        self.stats.tokens_evicted += 1;
        self.stats.mask_updates += 1;
        if self.blocks[block_idx].is_empty() {
            // Whole page finally drained — only now can it be reused.
            let blk = self.blocks.remove(block_idx);
            self.pool.release(blk.phys);
            self.stats.blocks_evicted += 1;
            self.stats.table_updates += 1;
        }
    }

    /// Bucket growth: runtime migrated the device buffer to a bigger
    /// capacity.
    pub fn grow(&mut self, new_capacity_blocks: usize) {
        self.pool.grow(new_capacity_blocks);
        self.stats.bucket_grows += 1;
    }

    // -- graph-input serialization -------------------------------------------

    /// Logical->physical table, padded with 0 to `nb` entries (padding is
    /// masked out via the validity mask so its value is irrelevant).
    pub fn block_table_i32(&self, nb: usize) -> Vec<i32> {
        assert!(self.blocks.len() <= nb, "table exceeds bucket");
        let mut t: Vec<i32> = self.blocks.iter().map(|b| b.phys as i32).collect();
        t.resize(nb, 0);
        t
    }

    /// Validity mask in logical order, flattened [nb * B].
    pub fn valid_mask_f32(&self, nb: usize) -> Vec<f32> {
        let mut m = vec![0.0f32; nb * self.block_size];
        for (bi, blk) in self.blocks.iter().enumerate() {
            for off in 0..blk.fill {
                if blk.is_live(off) {
                    m[bi * self.block_size + off] = 1.0;
                }
            }
        }
        m
    }

    /// (logical block idx, offset, position, scores) of every live token,
    /// oldest-first — the view token-level policies scan.
    pub fn live_token_list(&self) -> Vec<(usize, usize, u32, [f32; 3])> {
        let mut out = Vec::with_capacity(self.live_tokens());
        for (bi, blk) in self.blocks.iter().enumerate() {
            for (off, pos, sc) in blk.live_tokens() {
                out.push((bi, off, pos, sc));
            }
        }
        out
    }

    /// Consistency invariants — called by tests and debug assertions.
    pub fn check_invariants(&self) -> Result<(), String> {
        // physical slots unique and within capacity
        let mut seen = std::collections::HashSet::new();
        for b in &self.blocks {
            if b.phys >= self.pool.capacity() {
                return Err(format!("phys {} out of capacity", b.phys));
            }
            if !seen.insert(b.phys) {
                return Err(format!("duplicate phys slot {}", b.phys));
            }
            if b.fill > self.block_size {
                return Err("overfull block".into());
            }
        }
        // only the last block may be partially filled
        for (i, b) in self.blocks.iter().enumerate() {
            if i + 1 != self.blocks.len() && b.fill != self.block_size {
                return Err(format!("non-terminal block {i} not full"));
            }
        }
        // pool accounting adds up
        if self.pool.used() != self.blocks.len() {
            return Err(format!(
                "pool used {} != live blocks {}",
                self.pool.used(),
                self.blocks.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn sc(x: f32) -> [f32; 3] {
        [x, x, x]
    }

    #[test]
    fn prefill_then_decode_layout() {
        let mut c = SeqCache::new(4, 8);
        let toks: Vec<(u32, [f32; 3])> = (0..10).map(|i| (i, sc(i as f32))).collect();
        c.load_prefill(&toks, 10);
        assert_eq!(c.n_blocks(), 3);
        assert_eq!(c.live_tokens(), 10);
        assert_eq!(c.block_table_i32(8), vec![0, 1, 2, 0, 0, 0, 0, 0]);
        let m = c.valid_mask_f32(8);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 10);
        assert_eq!(&m[..10], &[1.0; 10]);
        // next write goes to block 2 offset 2 -> phys 2*4+2
        assert_eq!(c.peek_write_slot(), Some(10));
        c.check_invariants().unwrap();
    }

    #[test]
    fn prefill_after_eviction_keeps_original_positions() {
        let mut c = SeqCache::new(4, 8);
        // prompt of 16 tokens, evicted down to 8 (every other token)
        let toks: Vec<(u32, [f32; 3])> = (0..16).step_by(2).map(|i| (i, sc(0.0))).collect();
        c.load_prefill(&toks, 16);
        assert_eq!(c.next_position(), 16, "decode must continue at position 16");
        assert_eq!(c.live_tokens(), 8);
    }

    #[test]
    fn append_path() {
        let mut c = SeqCache::new(4, 4);
        assert!(c.ensure_block());
        assert_eq!(c.peek_write_slot(), Some(0));
        c.append(sc(1.0));
        assert_eq!(c.live_tokens(), 1);
        for _ in 0..3 {
            assert!(c.ensure_block());
            c.append(sc(1.0));
        }
        assert!(c.last_block_full());
        assert!(c.ensure_block());
        assert_eq!(c.peek_write_slot(), Some(4));
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_block_frees_slot_and_shifts_table() {
        let mut c = SeqCache::new(2, 4);
        let toks: Vec<(u32, [f32; 3])> = (0..6).map(|i| (i, sc(i as f32))).collect();
        c.load_prefill(&toks, 6);
        assert_eq!(c.n_blocks(), 3);
        c.evict_block(1); // drop tokens 2,3
        assert_eq!(c.n_blocks(), 2);
        assert_eq!(c.block_table_i32(4), vec![0, 2, 0, 0]);
        assert_eq!(c.live_tokens(), 4);
        // freed slot 1 is reused next
        assert!(c.ensure_block());
        assert_eq!(c.blocks().last().unwrap().phys, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn kill_token_drains_then_frees_block() {
        let mut c = SeqCache::new(2, 4);
        c.load_prefill(&(0..4).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 4);
        assert_eq!(c.n_blocks(), 2);
        c.kill_token(0, 0);
        assert_eq!(c.n_blocks(), 2, "partially dead block stays allocated");
        assert_eq!(c.partial_blocks(), 1);
        assert!(c.occupancy() < 1.0);
        c.kill_token(0, 1);
        assert_eq!(c.n_blocks(), 1, "drained block is freed");
        assert_eq!(c.stats.blocks_evicted, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn valid_mask_reflects_holes() {
        let mut c = SeqCache::new(4, 2);
        c.load_prefill(&(0..8).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 8);
        c.kill_token(1, 2);
        let m = c.valid_mask_f32(2);
        assert_eq!(m[6], 0.0);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 7);
    }

    #[test]
    fn grow_extends_pool() {
        let mut c = SeqCache::new(2, 2);
        c.load_prefill(&(0..4).map(|i| (i, sc(0.0))).collect::<Vec<_>>(), 4);
        assert!(c.needs_grow());
        c.grow(4);
        assert!(!c.needs_grow());
        assert!(c.ensure_block());
        c.append(sc(0.0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn property_random_op_sequences_keep_invariants() {
        propcheck::quick("seqcache-invariants", |rng| {
            let bs = *rng.choose(&[2usize, 4, 8, 16]);
            let cap = 4 + rng.usize_below(12);
            let mut c = SeqCache::new(bs, cap);
            let pre = rng.usize_below(cap * bs / 2) + 1;
            c.load_prefill(
                &(0..pre as u32).map(|i| (i, [rng.f32(), rng.f32(), rng.f32()])).collect::<Vec<_>>(),
                pre as u32,
            );
            for _ in 0..200 {
                match rng.below(10) {
                    0..=5 => {
                        if c.ensure_block() {
                            c.append([rng.f32(), rng.f32(), rng.f32()]);
                        } else if c.capacity_blocks() < 64 {
                            c.grow(c.capacity_blocks() + 2);
                        }
                    }
                    6..=7 => {
                        if c.n_blocks() > 1 {
                            let idx = c.n_blocks() - 1 - rng.usize_below(c.n_blocks() - 1).max(0);
                            // never evict the newest block (policy convention)
                            let idx = idx.min(c.n_blocks() - 2);
                            c.evict_block(idx);
                        }
                    }
                    _ => {
                        let live = c.live_token_list();
                        if live.len() > 1 {
                            let (bi, off, _, _) = live[rng.usize_below(live.len())];
                            c.kill_token(bi, off);
                        }
                    }
                }
                c.check_invariants().map_err(|e| e)?;
                // serialization shapes must always be consistent
                let nb = c.capacity_blocks();
                let t = c.block_table_i32(nb);
                let m = c.valid_mask_f32(nb);
                if t.len() != nb || m.len() != nb * bs {
                    return Err("bad serialization lengths".into());
                }
                let live_in_mask = m.iter().filter(|&&x| x == 1.0).count();
                if live_in_mask != c.live_tokens() {
                    return Err(format!(
                        "mask live {} != tracked {}",
                        live_in_mask,
                        c.live_tokens()
                    ));
                }
            }
            Ok(())
        });
    }
}
