//! Process-wide physical block arena with refcounted sharing, a
//! content-hash prefix index, batched block operations, and per-worker
//! slot caches.
//!
//! One `BlockManager` owns every physical KV slot in the server; each live
//! sequence ([`crate::kvcache::SeqCache`]) registers for a [`SeqId`] and
//! allocates/releases blocks through the shared handle. This replaces the
//! old per-sequence `BlockPool`: capacity is a single real number the
//! scheduler reads in O(1) (`used()` / `free_count()`), not an estimate
//! summed over running sequences, which is what makes admission gating and
//! preemption-under-memory-pressure expressible at all.
//!
//! **Sharing.** A slot can be held by several sequences at once: `alloc`
//! creates a private (refcount 1) claim, [`BlockManager::acquire_shared`]
//! adds another holder to a slot found through the prefix index, and
//! [`BlockManager::release`] drops one holder's claim — the slot returns
//! to the free list only when the LAST holder releases it (refcount 0).
//! `used()`/watermarks count a shared slot ONCE, which is the whole
//! memory win of prefix caching.
//!
//! **Prefix index.** [`BlockManager::publish`] maps a chained content hash
//! (see `seq_cache::prefix_block_hashes`) to a slot holding a FULL prompt
//! block. Later prefills walk their own chain through
//! [`BlockManager::acquire_shared_run`] and map the hits instead of
//! re-materializing them. An index entry is removed when its slot is freed
//! (refcount 0) or when the sole holder is about to mutate the content in
//! place ([`BlockManager::unpublish_slot`], driven by
//! `SeqCache::make_private`). Shared (refcount > 1) slots are FROZEN:
//! holders must copy-on-write before any in-place mutation, so an index
//! entry always describes the live content of its slot.
//!
//! Per-slot holder lists keep double frees and foreign frees (sequence A
//! releasing a claim it does not hold) hard errors in every build.
//!
//! **Lock discipline (PR 9).** The global mutex is taken O(1) times per
//! *sequence operation*, not per block:
//!
//!   * Batch APIs — [`BlockManager::alloc_many`],
//!     [`BlockManager::release_many`],
//!     [`BlockManager::acquire_shared_run`],
//!     [`BlockManager::publish_many`] — do a whole prefill load, cached
//!     prefill, restore, or `Drop` under ONE acquisition each.
//!   * Accounting reads — `used()`, `free_count()`, `capacity()`,
//!     `below_low_watermark()`, `above_high_watermark()`,
//!     `watermark_blocks()`, `prefix_epoch()`, and `stats()` — are pure
//!     atomic loads; the scheduler's hottest loop never touches the mutex.
//!   * Per-worker slot caches — [`BlockManager::with_worker_cache`]
//!     returns a handle bound to a small private stock of leased free
//!     slots, so the decode-time alloc/release steady state is entirely
//!     lock-free with respect to the global mutex. Leased slots count as
//!     FREE in watermark accounting (they are available capacity, merely
//!     parked near a worker); when the global free list runs dry, the
//!     allocator drains every peer cache before reporting `None`, so a
//!     worker can never see phantom OOM while slots idle in a peer's
//!     stock.
//!
//! Never are two of the three lock kinds (global `inner`, shard state,
//! cache registry) held at the same time — refills pop under the global
//! lock, drop it, then stow under the shard lock; drains collect under
//! shard locks, drop them, then splice under the global lock. That makes
//! the protocol deadlock-free by construction.
//!
//! Contention itself is observable: `inner()` goes through `try_lock`
//! first and counts `lock_acquisitions` / `contended_acquisitions`, and
//! the lease/drain protocol counts `cache_refills` / `cache_drains` — all
//! surfaced through [`ArenaStats`] into `CacheStats` and the SLO bench
//! JSON.
//!
//! The handle is `Clone + Send + Sync`; clones share both the arena and
//! (for handles made by `with_worker_cache`) the worker's slot cache, so a
//! `SeqCache` created from a bound handle allocs/frees through its
//! worker's cache with zero signature changes anywhere above.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError, Weak};

/// Leased free slots a worker cache holds at most. Small on purpose: the
/// lease is a latency optimization, not a reservation — a big stock would
/// just sit idle until a peer's dry-arena drain claws it back.
const SLOT_CACHE_CAP: usize = 8;

/// Identity of a registered sequence within one arena. Obtained from
/// [`BlockManager::register`]; ids are recycled after `unregister`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeqId(u32);

impl SeqId {
    pub fn raw(self) -> u32 {
        self.0
    }
}

/// Arena-wide accounting snapshot. Every field is an atomic load —
/// `stats()` never takes the global lock.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    pub capacity: usize,
    pub used: usize,
    /// High-water mark of simultaneously allocated blocks — the real
    /// physical-memory footprint of the whole server. A shared slot
    /// counts once, so prefix caching lowers this directly.
    pub peak_used: usize,
    /// Free slots currently leased into per-worker caches. Counted as
    /// FREE (not used): they are available capacity parked near a worker,
    /// reclaimable by any peer through the drain protocol.
    pub leased: usize,
    /// Private allocations (`alloc` / `alloc_many`); shared acquisitions
    /// are counted in `prefix_hits` instead.
    pub allocs: u64,
    /// Holder releases (both private frees and shared refcount drops).
    pub frees: u64,
    pub grows: u64,
    /// Live registered sequences.
    pub sequences: usize,
    /// Successful shared acquisitions — prompt blocks served from the
    /// prefix index instead of allocated.
    pub prefix_hits: u64,
    /// Slots currently published in the prefix index.
    pub published_blocks: usize,
    /// Global mutex acquisitions, total. The lock-count pin tests assert
    /// deltas of this counter around whole sequence operations.
    pub lock_acquisitions: u64,
    /// Acquisitions that found the mutex held (`try_lock` failed first).
    pub contended_acquisitions: u64,
    /// Times a worker cache refilled its stock from the global free list.
    pub cache_refills: u64,
    /// Times a dry allocation drained peer caches back into the free list.
    pub cache_drains: u64,
}

/// Per-slot holder set. Refcount is almost always 0 or 1 (sharing only
/// happens through the prefix index), so the two common states are inline
/// and allocation-free; only genuinely shared slots pay for a heap vector.
#[derive(Debug, Clone, PartialEq, Eq)]
enum Holders {
    Empty,
    One(u32),
    Many(Vec<u32>),
}

impl Holders {
    fn len(&self) -> usize {
        match self {
            Holders::Empty => 0,
            Holders::One(_) => 1,
            Holders::Many(v) => v.len(),
        }
    }

    fn is_empty(&self) -> bool {
        matches!(self, Holders::Empty)
    }

    fn contains(&self, id: u32) -> bool {
        match self {
            Holders::Empty => false,
            Holders::One(a) => *a == id,
            Holders::Many(v) => v.contains(&id),
        }
    }

    fn push(&mut self, id: u32) {
        match self {
            Holders::Empty => *self = Holders::One(id),
            Holders::One(a) => {
                let first = *a;
                *self = Holders::Many(vec![first, id]);
            }
            Holders::Many(v) => v.push(id),
        }
    }

    /// Remove one claim of `id`; returns false when `id` holds none.
    fn remove(&mut self, id: u32) -> bool {
        match self {
            Holders::Empty => false,
            Holders::One(a) if *a == id => {
                *self = Holders::Empty;
                true
            }
            Holders::One(_) => false,
            Holders::Many(v) => {
                let Some(pos) = v.iter().position(|&h| h == id) else {
                    return false;
                };
                v.swap_remove(pos);
                if v.len() == 1 {
                    let last = v[0];
                    *self = Holders::One(last);
                }
                true
            }
        }
    }

    /// Holder ids for error messages (rendered like the old `Vec` debug).
    fn ids(&self) -> Vec<u32> {
        match self {
            Holders::Empty => Vec::new(),
            Holders::One(a) => vec![*a],
            Holders::Many(v) => v.clone(),
        }
    }
}

#[derive(Debug)]
struct Inner {
    /// LIFO free list; initialized in reverse so slot 0 is handed out
    /// first (keeps the single-tenant layout identity tests rely on).
    free: Vec<usize>,
    /// `holders[phys]`: sequences holding a claim on the slot;
    /// `Holders::Empty` = free or leased/worker-cached.
    holders: Vec<Holders>,
    /// Claims held per registered id (indexed by raw id). Worker-cached
    /// claims live in the shard ledger instead; `owned_by` sums both.
    owned: Vec<usize>,
    registered: Vec<bool>,
    free_ids: Vec<u32>,
    /// Content hash -> slot, full prompt blocks only (the prefix index).
    prefix: HashMap<u64, usize>,
    /// `slot_hash[phys]`: the hash this slot is published under, if any.
    slot_hash: Vec<Option<u64>>,
    /// Admission watermark as a fraction of capacity (see
    /// [`BlockManager::set_watermarks`]). Stored as fractions so `grow`
    /// rescales the block thresholds automatically.
    low_frac: f64,
    /// Preemption watermark as a fraction of capacity.
    high_frac: f64,
}

/// One worker's slot cache: a private stock of leased free slots plus the
/// ledger of private claims served from it. Both live outside the global
/// holder table, so the decode steady state (alloc a block every
/// `page_size` tokens, release on eviction) never touches the global lock.
#[derive(Debug)]
struct Shard {
    shared: Arc<Shared>,
    state: Mutex<ShardState>,
}

#[derive(Debug, Default)]
struct ShardState {
    /// Leased free slots; `pop()` hands out the next one.
    stock: Vec<usize>,
    /// phys -> holder seq for private claims served from this cache.
    claims: HashMap<usize, u32>,
}

impl Shard {
    fn state(&self) -> MutexGuard<'_, ShardState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl Drop for Shard {
    fn drop(&mut self) {
        // Last bound handle gone (worker retired): everything the cache
        // still parks — stock, plus any leaked claims — goes home so no
        // slot is ever stranded.
        let st = self.state.get_mut().unwrap_or_else(|p| p.into_inner());
        let stock = std::mem::take(&mut st.stock);
        let leaked: Vec<usize> = st.claims.drain().map(|(phys, _)| phys).collect();
        self.shared.leased.fetch_sub(stock.len(), Relaxed);
        self.shared.note_freed(leaked.len());
        if !stock.is_empty() || !leaked.is_empty() {
            let mut g = self.shared.inner();
            g.free.extend(stock);
            g.free.extend(leaked);
        }
    }
}

#[derive(Debug)]
struct Shared {
    inner: Mutex<Inner>,
    /// Registry of live worker caches — the drain protocol's targets.
    shards: Mutex<Vec<Weak<Shard>>>,
    // -- lock-free accounting (read side of every hot scheduler check) --
    capacity: AtomicUsize,
    used: AtomicUsize,
    peak_used: AtomicUsize,
    leased: AtomicUsize,
    low_blocks: AtomicUsize,
    high_blocks: AtomicUsize,
    sequences: AtomicUsize,
    published: AtomicUsize,
    /// Bumped on every prefix-index mutation (publish or unpublish).
    /// Admission-time claim estimates are memoized against this: an
    /// unchanged epoch means `count_leading_hits` would return the same
    /// answer (see `scheduler::backend::ClaimMemo`).
    prefix_epoch: AtomicU64,
    allocs: AtomicU64,
    frees: AtomicU64,
    grows: AtomicU64,
    prefix_hits: AtomicU64,
    lock_acquisitions: AtomicU64,
    contended_acquisitions: AtomicU64,
    cache_refills: AtomicU64,
    cache_drains: AtomicU64,
}

impl Shared {
    /// Lock helper: `try_lock` first so contention is observable, then
    /// block. Ignores poisoning: the arena's invariants are restored
    /// before any panic below, and `SeqCache::drop` must still be able to
    /// return blocks while unwinding from an unrelated panic.
    fn inner(&self) -> MutexGuard<'_, Inner> {
        self.lock_acquisitions.fetch_add(1, Relaxed);
        match self.inner.try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.contended_acquisitions.fetch_add(1, Relaxed);
                self.inner.lock().unwrap_or_else(|p| p.into_inner())
            }
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
        }
    }

    /// `n` fresh private claims came into existence.
    fn note_claimed(&self, n: usize) {
        self.allocs.fetch_add(n as u64, Relaxed);
        let used = self.used.fetch_add(n, Relaxed) + n;
        self.peak_used.fetch_max(used, Relaxed);
    }

    /// `n` private (refcount-1) claims were released.
    fn note_freed(&self, n: usize) {
        if n > 0 {
            self.frees.fetch_add(n as u64, Relaxed);
            self.used.fetch_sub(n, Relaxed);
        }
    }

    /// Recompute the block watermarks from the stored fractions. Called
    /// under the global lock (serializes against `grow`/`set_watermarks`).
    fn store_watermarks(&self, g: &Inner, capacity: usize) {
        self.low_blocks.store((g.low_frac * capacity as f64).floor() as usize, Relaxed);
        self.high_blocks.store((g.high_frac * capacity as f64).floor() as usize, Relaxed);
    }

    /// Remove the index entry of `phys`, if any. Idempotent.
    fn unpublish(&self, g: &mut Inner, phys: usize) {
        if let Some(h) = g.slot_hash[phys].take() {
            g.prefix.remove(&h);
            self.prefix_epoch.fetch_add(1, Relaxed);
            self.published.fetch_sub(1, Relaxed);
        }
    }

    /// Drop one claim of `seq` on `phys`; frees (and unpublishes) the slot
    /// when the last claim goes. Returns an error message on a violation.
    fn drop_claim(&self, g: &mut Inner, seq: u32, phys: usize) -> Result<(), String> {
        if phys >= g.holders.len() {
            return Err(format!("release of out-of-range block {phys}"));
        }
        if g.holders[phys].is_empty() {
            return Err(format!("double free of block {phys}"));
        }
        if !g.holders[phys].remove(seq) {
            return Err(format!(
                "foreign free: seq {seq} releasing block {phys} held by seqs {:?}",
                g.holders[phys].ids()
            ));
        }
        g.owned[seq as usize] -= 1;
        self.frees.fetch_add(1, Relaxed);
        if g.holders[phys].is_empty() {
            self.unpublish(g, phys);
            g.free.push(phys);
            self.used.fetch_sub(1, Relaxed);
        }
        Ok(())
    }

    /// Snapshot the live worker caches (dead registry entries compacted).
    fn live_shards(&self) -> Vec<Arc<Shard>> {
        let mut reg = self.shards.lock().unwrap_or_else(|p| p.into_inner());
        reg.retain(|w| w.strong_count() > 0);
        reg.iter().filter_map(Weak::upgrade).collect()
    }

    /// Lease up to `cap` free slots out of the global free list.
    fn lease_batch(&self, cap: usize) -> Vec<usize> {
        let mut out = Vec::new();
        {
            let mut g = self.inner();
            let take = cap.min(g.free.len());
            for _ in 0..take {
                out.push(g.free.pop().expect("length checked"));
            }
        }
        if !out.is_empty() {
            self.leased.fetch_add(out.len(), Relaxed);
            self.cache_refills.fetch_add(1, Relaxed);
        }
        out
    }

    /// Dry-arena recovery: pull every worker cache's stock back into the
    /// global free list. Returns how many slots came home — 0 means the
    /// arena is genuinely out of memory and preemption is justified.
    fn drain_worker_caches(&self) -> usize {
        let shards = self.live_shards();
        let mut reclaimed: Vec<usize> = Vec::new();
        for s in &shards {
            let mut st = s.state();
            reclaimed.append(&mut st.stock);
        }
        let n = reclaimed.len();
        if n == 0 {
            return 0;
        }
        self.leased.fetch_sub(n, Relaxed);
        self.cache_drains.fetch_add(1, Relaxed);
        self.inner().free.extend(reclaimed);
        n
    }

    /// Pull every claim `seq` still holds out of the worker-cache ledgers
    /// (unregister leak-proofing). Returns the reclaimed slots; the caller
    /// pushes them onto the global free list.
    fn sweep_shard_claims(&self, seq: u32) -> Vec<usize> {
        let mut out = Vec::new();
        for s in &self.live_shards() {
            let mut st = s.state();
            st.claims.retain(|&phys, &mut holder| {
                if holder == seq {
                    out.push(phys);
                    false
                } else {
                    true
                }
            });
        }
        out
    }

    /// Cross-handle safety net: release a claim that lives in SOME
    /// worker's cache ledger. Returns true when found and freed.
    fn release_shard_claim(&self, seq: u32, phys: usize) -> bool {
        for s in &self.live_shards() {
            let mut st = s.state();
            if st.claims.get(&phys) == Some(&seq) {
                st.claims.remove(&phys);
                drop(st);
                self.note_freed(1);
                self.inner().free.push(phys);
                return true;
            }
        }
        false
    }

    /// Which sequence (if any) holds `phys` through a worker cache.
    fn shard_claim_holder(&self, phys: usize) -> Option<u32> {
        for s in &self.live_shards() {
            if let Some(&holder) = s.state().claims.get(&phys) {
                return Some(holder);
            }
        }
        None
    }

    /// Worker-cached claims held by `seq` across all caches.
    fn shard_claims_of(&self, seq: u32) -> usize {
        self.live_shards()
            .iter()
            .map(|s| s.state().claims.values().filter(|&&h| h == seq).count())
            .sum()
    }
}

/// Cloneable handle to the shared arena, optionally bound to one worker's
/// slot cache (see [`BlockManager::with_worker_cache`]).
#[derive(Debug, Clone)]
pub struct BlockManager {
    shared: Arc<Shared>,
    shard: Option<Arc<Shard>>,
}

impl BlockManager {
    pub fn new(capacity_blocks: usize) -> Self {
        let shared = Shared {
            inner: Mutex::new(Inner {
                free: (0..capacity_blocks).rev().collect(),
                holders: (0..capacity_blocks).map(|_| Holders::Empty).collect(),
                owned: Vec::new(),
                registered: Vec::new(),
                free_ids: Vec::new(),
                prefix: HashMap::new(),
                slot_hash: vec![None; capacity_blocks],
                // Default watermarks sit at capacity: admission gates on
                // raw physical headroom and proactive preemption never
                // fires — the historical hard-capacity semantics.
                low_frac: 1.0,
                high_frac: 1.0,
            }),
            shards: Mutex::new(Vec::new()),
            capacity: AtomicUsize::new(capacity_blocks),
            used: AtomicUsize::new(0),
            peak_used: AtomicUsize::new(0),
            leased: AtomicUsize::new(0),
            low_blocks: AtomicUsize::new(capacity_blocks),
            high_blocks: AtomicUsize::new(capacity_blocks),
            sequences: AtomicUsize::new(0),
            published: AtomicUsize::new(0),
            prefix_epoch: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            grows: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            lock_acquisitions: AtomicU64::new(0),
            contended_acquisitions: AtomicU64::new(0),
            cache_refills: AtomicU64::new(0),
            cache_drains: AtomicU64::new(0),
        };
        BlockManager { shared: Arc::new(shared), shard: None }
    }

    /// A clone of this handle bound to a fresh worker slot cache. Every
    /// clone of the RETURNED handle (e.g. the ones `SeqCache` keeps)
    /// shares the same cache, so a worker's scheduler and all its
    /// sequences alloc/free through one private stock. The cache returns
    /// everything it parks when its last handle drops.
    pub fn with_worker_cache(&self) -> BlockManager {
        let shard = Arc::new(Shard {
            shared: Arc::clone(&self.shared),
            state: Mutex::new(ShardState::default()),
        });
        self.shared
            .shards
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(Arc::downgrade(&shard));
        BlockManager { shared: Arc::clone(&self.shared), shard: Some(shard) }
    }

    /// Return this handle's cached stock (not its live claims) to the
    /// global free list. Idle workers call this so their lease does not
    /// sit parked while peers could use it without a drain. Returns how
    /// many slots went home; 0 for unbound handles.
    pub fn flush_local_cache(&self) -> usize {
        let Some(shard) = &self.shard else { return 0 };
        let stock = {
            let mut st = shard.state();
            std::mem::take(&mut st.stock)
        };
        if stock.is_empty() {
            return 0;
        }
        let n = stock.len();
        self.shared.leased.fetch_sub(n, Relaxed);
        self.shared.inner().free.extend(stock);
        n
    }

    /// Register a new sequence and return its arena identity.
    pub fn register(&self) -> SeqId {
        let mut g = self.shared.inner();
        let id = match g.free_ids.pop() {
            Some(id) => id,
            None => {
                let id = g.owned.len() as u32;
                g.owned.push(0);
                g.registered.push(false);
                id
            }
        };
        g.owned[id as usize] = 0;
        g.registered[id as usize] = true;
        self.shared.sequences.fetch_add(1, Relaxed);
        SeqId(id)
    }

    /// Drop a sequence: its id is recycled, and any claim it still holds
    /// — global or worker-cached — is released. Callers that know their
    /// slots (e.g. `SeqCache::drop`) release them first so the
    /// O(capacity) holder scan below only runs as a leak-proofing
    /// fallback.
    pub fn unregister(&self, seq: SeqId) {
        let reclaimed = self.shared.sweep_shard_claims(seq.0);
        self.shared.note_freed(reclaimed.len());
        let mut g = self.shared.inner();
        g.free.extend(reclaimed);
        let id = seq.0 as usize;
        if id >= g.registered.len() || !g.registered[id] {
            return; // already gone — unregister is idempotent for Drop
        }
        if g.owned[id] > 0 {
            for phys in 0..g.holders.len() {
                if g.holders[phys].contains(seq.0) {
                    self.shared.drop_claim(&mut g, seq.0, phys).expect("holder just found");
                }
            }
        }
        g.registered[id] = false;
        g.free_ids.push(seq.0);
        self.shared.sequences.fetch_sub(1, Relaxed);
    }

    /// Allocate one PRIVATE block for `seq` (refcount 1). Bound handles
    /// serve it from the worker's stock without the global lock; a dry
    /// arena drains peer caches before giving up. `None` only when no
    /// free slot exists anywhere (the scheduler's preemption trigger).
    pub fn alloc(&self, seq: SeqId) -> Option<usize> {
        if let Some(shard) = &self.shard {
            return self.alloc_cached(shard, seq);
        }
        loop {
            if let Some(phys) = self.try_alloc_global(seq) {
                return Some(phys);
            }
            if self.shared.drain_worker_caches() == 0 {
                // a racing free may have landed after our dry pass
                return self.try_alloc_global(seq);
            }
        }
    }

    fn try_alloc_global(&self, seq: SeqId) -> Option<usize> {
        let mut g = self.shared.inner();
        debug_assert!(g.registered[seq.0 as usize], "alloc on unregistered seq");
        let phys = g.free.pop()?;
        debug_assert!(g.holders[phys].is_empty() && g.slot_hash[phys].is_none());
        g.holders[phys].push(seq.0);
        g.owned[seq.0 as usize] += 1;
        drop(g);
        self.shared.note_claimed(1);
        Some(phys)
    }

    /// Bound-handle alloc: stock pop → lease refill → peer drain.
    fn alloc_cached(&self, shard: &Shard, seq: SeqId) -> Option<usize> {
        {
            let mut st = shard.state();
            if let Some(phys) = st.stock.pop() {
                st.claims.insert(phys, seq.0);
                drop(st);
                self.shared.leased.fetch_sub(1, Relaxed);
                self.shared.note_claimed(1);
                return Some(phys);
            }
        }
        loop {
            let batch = self.shared.lease_batch(SLOT_CACHE_CAP);
            if !batch.is_empty() {
                let mut st = shard.state();
                // reverse so pop order matches global free-list LIFO order
                st.stock.extend(batch.into_iter().rev());
                let phys = st.stock.pop().expect("batch non-empty");
                st.claims.insert(phys, seq.0);
                drop(st);
                self.shared.leased.fetch_sub(1, Relaxed);
                self.shared.note_claimed(1);
                return Some(phys);
            }
            if self.shared.drain_worker_caches() == 0 {
                return None;
            }
        }
    }

    /// Allocate `n` PRIVATE blocks for `seq` under ONE global lock
    /// acquisition, all-or-nothing. Slot order is identical to `n`
    /// sequential `alloc` calls on an unbound handle. Drains peer caches
    /// when the free list alone cannot cover `n`; `None` means the arena
    /// genuinely lacks `n` free slots.
    pub fn alloc_many(&self, seq: SeqId, n: usize) -> Option<Vec<usize>> {
        if n == 0 {
            return Some(Vec::new());
        }
        loop {
            if let Some(v) = self.try_alloc_many(seq, n) {
                return Some(v);
            }
            if self.shared.drain_worker_caches() == 0 {
                return self.try_alloc_many(seq, n);
            }
        }
    }

    fn try_alloc_many(&self, seq: SeqId, n: usize) -> Option<Vec<usize>> {
        let mut g = self.shared.inner();
        debug_assert!(g.registered[seq.0 as usize], "alloc on unregistered seq");
        if g.free.len() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let phys = g.free.pop().expect("length checked");
            debug_assert!(g.holders[phys].is_empty() && g.slot_hash[phys].is_none());
            g.holders[phys].push(seq.0);
            out.push(phys);
        }
        g.owned[seq.0 as usize] += n;
        drop(g);
        self.shared.note_claimed(n);
        Some(out)
    }

    /// Look up `hash` in the prefix index and, on a hit, add `seq` as a
    /// holder of the published slot (refcount + 1; `used()` unchanged —
    /// that is the memory saving). `None` on a miss, or when `seq` already
    /// holds the slot (a sequence maps each physical page at most once).
    pub fn acquire_shared(&self, seq: SeqId, hash: u64) -> Option<usize> {
        let mut g = self.shared.inner();
        debug_assert!(g.registered[seq.0 as usize], "acquire on unregistered seq");
        let phys = *g.prefix.get(&hash)?;
        if g.holders[phys].contains(seq.0) {
            return None;
        }
        g.holders[phys].push(seq.0);
        g.owned[seq.0 as usize] += 1;
        drop(g);
        self.shared.prefix_hits.fetch_add(1, Relaxed);
        Some(phys)
    }

    /// Walk `hashes` through the prefix index under ONE lock acquisition,
    /// acquiring each hit for `seq` until the first miss (or a slot `seq`
    /// already holds). Returns the acquired slots in chain order —
    /// observationally identical to calling `acquire_shared` per hash
    /// until it returns `None`.
    pub fn acquire_shared_run(&self, seq: SeqId, hashes: &[u64]) -> Vec<usize> {
        let mut out = Vec::new();
        if hashes.is_empty() {
            return out;
        }
        let mut g = self.shared.inner();
        debug_assert!(g.registered[seq.0 as usize], "acquire on unregistered seq");
        for h in hashes {
            let Some(&phys) = g.prefix.get(h) else { break };
            if g.holders[phys].contains(seq.0) {
                break;
            }
            g.holders[phys].push(seq.0);
            g.owned[seq.0 as usize] += 1;
            out.push(phys);
        }
        drop(g);
        self.shared.prefix_hits.fetch_add(out.len() as u64, Relaxed);
        out
    }

    /// Migrate a worker-cached claim into the global holder table (the
    /// prefix index only tracks global holders). No-op when `phys` is not
    /// cached here. Accounting is unchanged: the claim already counted.
    fn promote_shard_claim(&self, seq: u32, phys: usize) {
        let Some(shard) = &self.shard else { return };
        let promote = {
            let mut st = shard.state();
            if st.claims.get(&phys) == Some(&seq) {
                st.claims.remove(&phys);
                true
            } else {
                false
            }
        };
        if promote {
            let mut g = self.shared.inner();
            g.holders[phys].push(seq);
            g.owned[seq as usize] += 1;
        }
    }

    /// Publish the content hash of a FULL block held by `seq` into the
    /// prefix index, making it shareable. First publisher wins: returns
    /// `false` (and indexes nothing) when the hash is already mapped, when
    /// the slot is already published under another hash, or when `seq`
    /// does not hold the slot.
    pub fn publish(&self, seq: SeqId, phys: usize, hash: u64) -> bool {
        self.promote_shard_claim(seq.0, phys);
        let mut g = self.shared.inner();
        if phys >= g.holders.len() || !g.holders[phys].contains(seq.0) {
            return false;
        }
        if g.slot_hash[phys].is_some() || g.prefix.contains_key(&hash) {
            return false;
        }
        g.prefix.insert(hash, phys);
        g.slot_hash[phys] = Some(hash);
        drop(g);
        self.shared.prefix_epoch.fetch_add(1, Relaxed);
        self.shared.published.fetch_add(1, Relaxed);
        true
    }

    /// Publish a run of `(phys, hash)` pairs under ONE lock acquisition.
    /// Per-pair first-publisher-wins semantics identical to `publish`;
    /// returns one success flag per pair, in order.
    pub fn publish_many(&self, seq: SeqId, entries: &[(usize, u64)]) -> Vec<bool> {
        if entries.is_empty() {
            return Vec::new();
        }
        for &(phys, _) in entries {
            self.promote_shard_claim(seq.0, phys);
        }
        let mut g = self.shared.inner();
        let mut out = Vec::with_capacity(entries.len());
        let mut published = 0usize;
        for &(phys, hash) in entries {
            let ok = phys < g.holders.len()
                && g.holders[phys].contains(seq.0)
                && g.slot_hash[phys].is_none()
                && !g.prefix.contains_key(&hash);
            if ok {
                g.prefix.insert(hash, phys);
                g.slot_hash[phys] = Some(hash);
                published += 1;
            }
            out.push(ok);
        }
        drop(g);
        if published > 0 {
            // one epoch bump per batch: any change invalidates claim memos
            self.shared.prefix_epoch.fetch_add(1, Relaxed);
            self.shared.published.fetch_add(published, Relaxed);
        }
        out
    }

    /// Remove `phys` from the prefix index (sole holder about to mutate
    /// the content in place). Idempotent; no-op for unpublished slots.
    pub fn unpublish_slot(&self, phys: usize) {
        let mut g = self.shared.inner();
        if phys < g.holders.len() {
            self.shared.unpublish(&mut g, phys);
        }
    }

    /// Current holder count of `phys` (0 = free). A result > 1 means the
    /// slot is shared and must be copied-on-write before in-place writes.
    /// A worker-cached private claim reads as 1.
    pub fn refcount(&self, phys: usize) -> usize {
        {
            let g = self.shared.inner();
            let n = g.holders.get(phys).map_or(0, Holders::len);
            if n > 0 {
                return n;
            }
        }
        if self.shared.shard_claim_holder(phys).is_some() {
            1
        } else {
            0
        }
    }

    /// Generation counter of the prefix index: changes exactly when a
    /// publish or unpublish changes what `count_leading_hits` could
    /// answer. The admission claim-memoization key. Lock-free.
    pub fn prefix_epoch(&self) -> u64 {
        self.shared.prefix_epoch.load(Relaxed)
    }

    /// True when `phys` is currently published in the prefix index.
    pub fn is_published(&self, phys: usize) -> bool {
        let g = self.shared.inner();
        phys < g.slot_hash.len() && g.slot_hash[phys].is_some()
    }

    /// How many LEADING entries of `hashes` are currently published — the
    /// admission-time estimate of how many prompt blocks a prefill would
    /// map from the index instead of allocating. Read-only: acquires
    /// nothing (the walk in `try_load_prefill_cached` does the claiming).
    pub fn count_leading_hits(&self, hashes: &[u64]) -> usize {
        let g = self.shared.inner();
        hashes.iter().take_while(|h| g.prefix.contains_key(h)).count()
    }

    /// Release one claim of `seq` on `phys`: the slot returns to the free
    /// list (and leaves the prefix index) only when the LAST claim goes.
    /// Worker-cached claims return to the worker's stock without the
    /// global lock. Panics on double free (slot already free) and on
    /// foreign free (`seq` holds no claim on the slot) — both are
    /// memory-safety bugs in the caller, checked in every build.
    pub fn release(&self, seq: SeqId, phys: usize) {
        if let Some(shard) = &self.shard {
            if self.release_cached(shard, seq, phys) {
                return;
            }
        }
        let mut g = self.shared.inner();
        if let Err(msg) = self.shared.drop_claim(&mut g, seq.0, phys) {
            drop(g); // release the lock before unwinding or scanning peers
            if self.shared.release_shard_claim(seq.0, phys) {
                return; // cross-handle release of a peer-cached claim
            }
            panic!("{msg}");
        }
    }

    /// Try to release through this worker's cache ledger. True when the
    /// claim lived here and was returned to stock (or overflowed back to
    /// the global free list).
    fn release_cached(&self, shard: &Shard, seq: SeqId, phys: usize) -> bool {
        let mut st = shard.state();
        match st.claims.get(&phys).copied() {
            None => false,
            Some(holder) if holder == seq.0 => {
                st.claims.remove(&phys);
                let overflow = if st.stock.len() < SLOT_CACHE_CAP {
                    st.stock.push(phys);
                    self.shared.leased.fetch_add(1, Relaxed);
                    None
                } else {
                    Some(phys)
                };
                drop(st);
                self.shared.note_freed(1);
                if let Some(p) = overflow {
                    self.shared.inner().free.push(p);
                }
                true
            }
            Some(holder) => {
                drop(st);
                panic!("foreign free: seq {} releasing block {phys} held by seqs [{holder}]", seq.0);
            }
        }
    }

    /// Release a whole set of claims of `seq` under O(1) lock
    /// acquisitions: one pass over the worker cache ledger (when bound),
    /// one global acquisition for everything else. Per-slot semantics —
    /// refcount drops, last-holder frees, double/foreign-free panics —
    /// are identical to calling `release` per slot, in order.
    pub fn release_many(&self, seq: SeqId, slots: &[usize]) {
        if slots.is_empty() {
            return;
        }
        let mut rest: Vec<usize> = Vec::new();
        if let Some(shard) = &self.shard {
            let mut overflow: Vec<usize> = Vec::new();
            let mut returned = 0usize;
            {
                let mut st = shard.state();
                for &phys in slots {
                    match st.claims.get(&phys).copied() {
                        Some(holder) if holder == seq.0 => {
                            st.claims.remove(&phys);
                            if st.stock.len() < SLOT_CACHE_CAP {
                                st.stock.push(phys);
                                self.shared.leased.fetch_add(1, Relaxed);
                            } else {
                                overflow.push(phys);
                            }
                            returned += 1;
                        }
                        Some(holder) => {
                            drop(st);
                            panic!(
                                "foreign free: seq {} releasing block {phys} held by seqs [{holder}]",
                                seq.0
                            );
                        }
                        None => rest.push(phys),
                    }
                }
            }
            self.shared.note_freed(returned);
            if !overflow.is_empty() {
                self.shared.inner().free.extend(overflow);
            }
        } else {
            rest.extend_from_slice(slots);
        }
        if rest.is_empty() {
            return;
        }
        let mut guard = Some(self.shared.inner());
        for &phys in &rest {
            let g = guard.as_mut().expect("guard live");
            if let Err(msg) = self.shared.drop_claim(g, seq.0, phys) {
                guard = None; // drop the lock before scanning peers / unwinding
                if self.shared.release_shard_claim(seq.0, phys) {
                    guard = Some(self.shared.inner());
                } else {
                    panic!("{msg}");
                }
            }
        }
    }

    /// Extend the arena to `new_capacity` slots (device memory growth).
    pub fn grow(&self, new_capacity: usize) {
        let mut g = self.shared.inner();
        let old = g.holders.len();
        assert!(new_capacity >= old, "arena cannot shrink");
        for p in (old..new_capacity).rev() {
            g.free.push(p);
        }
        g.holders.resize_with(new_capacity, || Holders::Empty);
        g.slot_hash.resize(new_capacity, None);
        self.shared.capacity.store(new_capacity, Relaxed);
        self.shared.store_watermarks(&g, new_capacity);
        self.shared.grows.fetch_add(1, Relaxed);
    }

    /// Configure the admission/preemption hysteresis band as fractions of
    /// capacity (rescaled automatically on `grow`). The scheduler admits a
    /// sequence only while usage would stay at or below the LOW mark and
    /// preempts once usage exceeds the HIGH mark; the gap between them
    /// absorbs decode-time growth so optimistic admission cannot thrash.
    pub fn set_watermarks(&self, low: f64, high: f64) {
        assert!(
            low > 0.0 && low <= high && high <= 1.0,
            "watermarks must satisfy 0 < low <= high <= 1 (got {low}, {high})"
        );
        let mut g = self.shared.inner();
        g.low_frac = low;
        g.high_frac = high;
        let capacity = g.holders.len();
        self.shared.store_watermarks(&g, capacity);
    }

    /// `(low, high)` watermarks in blocks at the current capacity.
    /// Lock-free.
    pub fn watermark_blocks(&self) -> (usize, usize) {
        (self.shared.low_blocks.load(Relaxed), self.shared.high_blocks.load(Relaxed))
    }

    /// True when allocating `incoming` more blocks keeps usage at or below
    /// the low watermark — the scheduler's admission gate. With default
    /// watermarks (1.0) this degenerates to "fits physical capacity".
    /// Lock-free: leased (worker-cached) slots count as free.
    pub fn below_low_watermark(&self, incoming: usize) -> bool {
        self.shared.used.load(Relaxed) + incoming <= self.shared.low_blocks.load(Relaxed)
    }

    /// True when usage exceeds the high watermark — the scheduler's
    /// proactive preemption trigger (reclaims the optimism the low-mark
    /// admission gate extends). Never true with default watermarks.
    /// Lock-free.
    pub fn above_high_watermark(&self) -> bool {
        self.shared.used.load(Relaxed) > self.shared.high_blocks.load(Relaxed)
    }

    /// Lock-free.
    pub fn capacity(&self) -> usize {
        self.shared.capacity.load(Relaxed)
    }

    /// Free slots from the global view: unallocated, whether on the global
    /// free list or leased into a worker cache. Lock-free.
    pub fn free_count(&self) -> usize {
        self.shared.capacity.load(Relaxed) - self.shared.used.load(Relaxed)
    }

    /// Allocated (claimed) slots; a shared slot counts once. Lock-free.
    pub fn used(&self) -> usize {
        self.shared.used.load(Relaxed)
    }

    /// Claims currently held by `seq` (a shared slot counts one claim per
    /// holder), global and worker-cached both.
    pub fn owned_by(&self, seq: SeqId) -> usize {
        let global = {
            let g = self.shared.inner();
            g.owned.get(seq.0 as usize).copied().unwrap_or(0)
        };
        global + self.shared.shard_claims_of(seq.0)
    }

    /// Accounting snapshot. Pure atomic loads — never takes the lock (and
    /// therefore never perturbs the `lock_acquisitions` it reports).
    pub fn stats(&self) -> ArenaStats {
        let s = &self.shared;
        ArenaStats {
            capacity: s.capacity.load(Relaxed),
            used: s.used.load(Relaxed),
            peak_used: s.peak_used.load(Relaxed),
            leased: s.leased.load(Relaxed),
            allocs: s.allocs.load(Relaxed),
            frees: s.frees.load(Relaxed),
            grows: s.grows.load(Relaxed),
            sequences: s.sequences.load(Relaxed),
            prefix_hits: s.prefix_hits.load(Relaxed),
            published_blocks: s.published.load(Relaxed),
            lock_acquisitions: s.lock_acquisitions.load(Relaxed),
            contended_acquisitions: s.contended_acquisitions.load(Relaxed),
            cache_refills: s.cache_refills.load(Relaxed),
            cache_drains: s.cache_drains.load(Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let m = BlockManager::new(3);
        let s = m.register();
        assert_eq!(m.alloc(s), Some(0));
        assert_eq!(m.alloc(s), Some(1));
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), None);
        assert_eq!(m.used(), 3);
        m.release(s, 1);
        assert_eq!(m.alloc(s), Some(1), "LIFO reuse of the freed slot");
        assert_eq!(m.stats().peak_used, 3);
    }

    #[test]
    fn per_seq_ownership_is_tracked() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let p0 = m.alloc(a).unwrap();
        let _p1 = m.alloc(b).unwrap();
        let _p2 = m.alloc(b).unwrap();
        assert_eq!(m.owned_by(a), 1);
        assert_eq!(m.owned_by(b), 2);
        assert_eq!(m.used(), 3);
        m.release(a, p0);
        assert_eq!(m.owned_by(a), 0);
        assert_eq!(m.free_count(), 2);
    }

    #[test]
    fn unregister_releases_everything() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        m.alloc(a).unwrap();
        m.alloc(a).unwrap();
        m.alloc(b).unwrap();
        m.unregister(a);
        assert_eq!(m.used(), 1, "a's blocks returned to the arena");
        assert_eq!(m.stats().sequences, 1);
        m.unregister(a); // idempotent
        assert_eq!(m.used(), 1);
    }

    #[test]
    fn grow_extends_capacity() {
        let m = BlockManager::new(2);
        let s = m.register();
        m.alloc(s).unwrap();
        m.alloc(s).unwrap();
        assert_eq!(m.alloc(s), None);
        m.grow(4);
        assert_eq!(m.capacity(), 4);
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), Some(3));
        assert_eq!(m.stats().grows, 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let m = BlockManager::new(2);
        let s = m.register();
        let p = m.alloc(s).unwrap();
        m.release(s, p);
        m.release(s, p);
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn foreign_free_panics() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        m.release(b, p);
    }

    #[test]
    fn shared_slot_frees_only_at_refcount_zero() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        assert!(m.publish(a, p, 0xfeed));
        assert_eq!(m.acquire_shared(b, 0xfeed), Some(p));
        assert_eq!(m.refcount(p), 2);
        assert_eq!(m.used(), 1, "a shared slot counts once");
        assert_eq!(m.owned_by(a), 1);
        assert_eq!(m.owned_by(b), 1);
        m.release(a, p);
        assert_eq!(m.refcount(p), 1, "b's claim keeps the slot alive");
        assert_eq!(m.used(), 1);
        assert!(m.is_published(p), "surviving holders keep the index entry");
        m.release(b, p);
        assert_eq!(m.refcount(p), 0);
        assert_eq!(m.used(), 0);
        assert!(!m.is_published(p), "freeing the slot removes it from the index");
        assert_eq!(m.acquire_shared(b, 0xfeed), None, "stale hash no longer hits");
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn released_sharer_cannot_release_twice() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        assert!(m.publish(a, p, 7));
        assert_eq!(m.acquire_shared(b, 7), Some(p));
        m.release(b, p);
        m.release(b, p); // a still holds the slot: this is a foreign free
    }

    #[test]
    fn publish_is_first_wins_and_holder_only() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let pa = m.alloc(a).unwrap();
        let pb = m.alloc(b).unwrap();
        assert!(!m.publish(b, pa, 1), "only a holder may publish a slot");
        assert!(m.publish(a, pa, 1));
        assert!(!m.publish(b, pb, 1), "hash already mapped: first publisher wins");
        assert!(!m.publish(a, pa, 2), "slot already published under another hash");
        assert_eq!(m.stats().published_blocks, 1);
        m.unpublish_slot(pa);
        assert!(!m.is_published(pa));
        assert_eq!(m.acquire_shared(b, 1), None);
        assert_eq!(m.refcount(pa), 1, "unpublish does not release the holder");
    }

    #[test]
    fn count_leading_hits_walks_the_chain() {
        let m = BlockManager::new(4);
        let a = m.register();
        for (i, h) in [10u64, 11, 12].iter().enumerate() {
            let p = m.alloc(a).unwrap();
            assert_eq!(p, i);
            assert!(m.publish(a, p, *h));
        }
        assert_eq!(m.count_leading_hits(&[10, 11, 12]), 3);
        assert_eq!(m.count_leading_hits(&[10, 99, 12]), 1, "stops at the first miss");
        assert_eq!(m.count_leading_hits(&[99]), 0);
        assert_eq!(m.count_leading_hits(&[]), 0);
        assert_eq!(m.stats().prefix_hits, 0, "counting acquires nothing");
    }

    #[test]
    fn unregister_drops_shared_claims_without_freeing_live_slots() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        assert!(m.publish(a, p, 3));
        assert_eq!(m.acquire_shared(b, 3), Some(p));
        m.alloc(b).unwrap();
        m.unregister(b);
        assert_eq!(m.refcount(p), 1, "a's claim survives b's unregister");
        assert_eq!(m.used(), 1, "b's private block was freed");
        assert!(m.is_published(p));
    }

    #[test]
    fn default_watermarks_are_hard_capacity() {
        let m = BlockManager::new(10);
        assert_eq!(m.watermark_blocks(), (10, 10));
        let s = m.register();
        for _ in 0..10 {
            m.alloc(s).unwrap();
        }
        assert!(!m.above_high_watermark(), "high mark at capacity never trips");
        assert!(m.below_low_watermark(0));
        assert!(!m.below_low_watermark(1));
    }

    #[test]
    fn watermark_band_gates_and_trips() {
        let m = BlockManager::new(20);
        m.set_watermarks(0.5, 0.75); // low = 10 blocks, high = 15 blocks
        assert_eq!(m.watermark_blocks(), (10, 15));
        let s = m.register();
        for _ in 0..8 {
            m.alloc(s).unwrap();
        }
        assert!(m.below_low_watermark(2), "8 + 2 == low");
        assert!(!m.below_low_watermark(3), "8 + 3 crosses the low mark");
        assert!(!m.above_high_watermark());
        for _ in 0..8 {
            m.alloc(s).unwrap();
        }
        assert!(m.above_high_watermark(), "16 > high mark 15");
    }

    #[test]
    fn watermarks_rescale_on_grow() {
        let m = BlockManager::new(10);
        m.set_watermarks(0.5, 0.8);
        assert_eq!(m.watermark_blocks(), (5, 8));
        m.grow(20);
        assert_eq!(m.watermark_blocks(), (10, 16), "fractions track capacity");
    }

    #[test]
    #[should_panic(expected = "watermarks must satisfy")]
    fn inverted_watermarks_rejected() {
        BlockManager::new(4).set_watermarks(0.9, 0.5);
    }

    #[test]
    fn prefix_epoch_tracks_index_mutations_only() {
        let m = BlockManager::new(4);
        let a = m.register();
        let e0 = m.prefix_epoch();
        let p = m.alloc(a).unwrap();
        assert_eq!(m.prefix_epoch(), e0, "private alloc leaves the index alone");
        assert!(m.publish(a, p, 42));
        let e1 = m.prefix_epoch();
        assert!(e1 > e0, "publish bumps the epoch");
        assert!(!m.publish(a, p, 43), "already published");
        assert_eq!(m.prefix_epoch(), e1, "failed publish does not bump");
        m.unpublish_slot(p);
        let e2 = m.prefix_epoch();
        assert!(e2 > e1, "unpublish bumps the epoch");
        m.unpublish_slot(p); // idempotent: nothing to remove
        assert_eq!(m.prefix_epoch(), e2);
        let q = m.alloc(a).unwrap();
        assert!(m.publish(a, q, 44));
        let e3 = m.prefix_epoch();
        m.release(a, q);
        assert!(m.prefix_epoch() > e3, "freeing a published slot unpublishes");
    }

    #[test]
    fn id_recycling() {
        let m = BlockManager::new(2);
        let a = m.register();
        let raw = a.raw();
        m.unregister(a);
        let b = m.register();
        assert_eq!(b.raw(), raw, "freed id is recycled");
    }

    // ---- PR 9: batch APIs, lock counting, worker slot caches ----

    #[test]
    fn alloc_many_matches_sequential_layout_and_one_lock() {
        let m = BlockManager::new(8);
        let s = m.register();
        let before = m.stats().lock_acquisitions;
        let v = m.alloc_many(s, 3).unwrap();
        assert_eq!(m.stats().lock_acquisitions - before, 1, "one acquisition for 3 blocks");
        assert_eq!(v, vec![0, 1, 2], "identical layout to sequential alloc");
        assert_eq!(m.used(), 3);
        assert_eq!(m.owned_by(s), 3);
        m.release_many(s, &v);
        assert_eq!(m.used(), 0);
        // LIFO reuse: the batch frees pushed 0,1,2 so the next batch
        // pops 2,1,0 — exactly what three sequential alloc/release
        // round-trips would produce.
        assert_eq!(m.alloc_many(s, 3).unwrap(), vec![2, 1, 0]);
        assert_eq!(m.alloc_many(s, 99), None, "all-or-nothing on overflow");
        assert_eq!(m.used(), 3, "failed batch claims nothing");
        assert_eq!(m.alloc_many(s, 0), Some(Vec::new()));
    }

    #[test]
    #[should_panic(expected = "double free of block")]
    fn release_many_double_free_panics() {
        let m = BlockManager::new(4);
        let s = m.register();
        let v = m.alloc_many(s, 2).unwrap();
        m.release_many(s, &[v[0], v[0]]);
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn release_many_foreign_free_panics() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let v = m.alloc_many(a, 2).unwrap();
        m.release_many(b, &v);
    }

    #[test]
    fn acquire_shared_run_walks_and_stops_like_per_block_calls() {
        let m = BlockManager::new(8);
        let a = m.register();
        let slots = m.alloc_many(a, 3).unwrap();
        let pairs: Vec<(usize, u64)> = slots.iter().map(|&p| (p, 100 + p as u64)).collect();
        assert_eq!(m.publish_many(a, &pairs), vec![true, true, true]);
        let b = m.register();
        let before = m.stats().lock_acquisitions;
        let run = m.acquire_shared_run(b, &[100, 101, 999, 102]);
        assert_eq!(m.stats().lock_acquisitions - before, 1);
        assert_eq!(run, &slots[..2], "stops at the first miss");
        assert_eq!(m.stats().prefix_hits, 2);
        assert_eq!(m.owned_by(b), 2);
        // already-held slots stop the walk, exactly like acquire_shared
        assert_eq!(m.acquire_shared_run(b, &[100, 101]), Vec::<usize>::new());
        assert_eq!(m.acquire_shared_run(b, &[102]), vec![slots[2]]);
        assert_eq!(m.acquire_shared_run(b, &[]), Vec::<usize>::new());
    }

    #[test]
    fn publish_many_is_first_wins_per_pair() {
        let m = BlockManager::new(4);
        let a = m.register();
        let v = m.alloc_many(a, 2).unwrap();
        let e0 = m.prefix_epoch();
        let ok = m.publish_many(a, &[(v[0], 7), (v[1], 7), (v[1], 8)]);
        assert_eq!(ok, vec![true, false, true], "duplicate hash loses, fresh hash wins");
        assert_eq!(m.stats().published_blocks, 2);
        assert!(m.prefix_epoch() > e0);
        let e1 = m.prefix_epoch();
        assert_eq!(m.publish_many(a, &[(v[0], 9)]), vec![false]);
        assert_eq!(m.prefix_epoch(), e1, "all-failed batch does not bump the epoch");
    }

    #[test]
    fn worker_cache_steady_state_skips_the_global_lock() {
        let m = BlockManager::new(32);
        let w = m.with_worker_cache();
        let s = w.register();
        let p = w.alloc(s).unwrap();
        w.release(s, p);
        // warmed up: the stock now covers the loop below
        let before = m.stats().lock_acquisitions;
        for _ in 0..50 {
            let p = w.alloc(s).unwrap();
            w.release(s, p);
        }
        assert_eq!(m.stats().lock_acquisitions, before, "steady state is lock-free");
        assert_eq!(m.stats().cache_refills, 1);
        assert_eq!(m.used(), 0);
        assert!(m.stats().leased > 0, "the lease is parked at the worker");
        assert_eq!(m.free_count(), 32, "leased slots still count as free");
    }

    #[test]
    fn worker_cached_claims_are_visible_and_releasable() {
        let m = BlockManager::new(16);
        let w = m.with_worker_cache();
        let s = w.register();
        let p = w.alloc(s).unwrap();
        assert_eq!(m.used(), 1);
        assert_eq!(w.refcount(p), 1, "cached private claim reads as refcount 1");
        assert_eq!(w.owned_by(s), 1);
        // cross-handle release through the unbound handle still works
        m.release(s, p);
        assert_eq!(m.used(), 0);
        assert_eq!(w.refcount(p), 0);
        assert_eq!(w.owned_by(s), 0);
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn worker_cache_foreign_free_panics() {
        let m = BlockManager::new(8);
        let w = m.with_worker_cache();
        let a = w.register();
        let b = w.register();
        let p = w.alloc(a).unwrap();
        w.release(b, p);
    }

    #[test]
    fn dry_arena_drains_peer_caches_instead_of_failing() {
        let m = BlockManager::new(SLOT_CACHE_CAP);
        let w = m.with_worker_cache();
        let ws = w.register();
        let p = w.alloc(ws).unwrap(); // leases the whole arena into w's cache
        assert_eq!(m.stats().leased, SLOT_CACHE_CAP - 1);
        let b = m.register();
        // global free list is empty, but peers hold stock: no phantom OOM
        let v = m.alloc_many(b, SLOT_CACHE_CAP - 1).expect("drain must cover this");
        assert_eq!(v.len(), SLOT_CACHE_CAP - 1);
        assert!(m.stats().cache_drains >= 1);
        assert_eq!(m.stats().leased, 0);
        assert_eq!(m.used(), SLOT_CACHE_CAP);
        assert_eq!(m.alloc(b), None, "now the arena is genuinely dry");
        w.release(ws, p);
        m.release_many(b, &v);
        assert_eq!(m.used(), 0);
    }

    #[test]
    fn unregister_sweeps_worker_cached_claims() {
        let m = BlockManager::new(16);
        let w = m.with_worker_cache();
        let s = w.register();
        w.alloc(s).unwrap();
        w.alloc(s).unwrap();
        assert_eq!(m.used(), 2);
        w.unregister(s);
        assert_eq!(m.used(), 0, "cached claims reclaimed on unregister");
        assert_eq!(m.stats().sequences, 0);
    }

    #[test]
    fn flush_and_drop_return_the_stock() {
        let m = BlockManager::new(16);
        {
            let w = m.with_worker_cache();
            let s = w.register();
            let p = w.alloc(s).unwrap();
            w.release(s, p);
            assert!(m.stats().leased > 0);
            assert_eq!(w.flush_local_cache(), SLOT_CACHE_CAP);
            assert_eq!(m.stats().leased, 0);
            assert_eq!(m.flush_local_cache(), 0, "unbound handles hold no stock");
            let _p2 = w.alloc(s).unwrap(); // re-lease, then drop the worker
            w.unregister(s);
        }
        assert_eq!(m.stats().leased, 0, "dropping the last bound handle restocks");
        assert_eq!(m.used(), 0);
        assert_eq!(m.free_count(), 16);
    }

    #[test]
    fn watermarks_count_leased_slots_as_free() {
        let m = BlockManager::new(20);
        m.set_watermarks(0.5, 0.75); // low = 10, high = 15
        let w = m.with_worker_cache();
        let s = w.register();
        w.alloc(s).unwrap(); // leases SLOT_CACHE_CAP, uses 1
        assert_eq!(m.used(), 1);
        assert!(m.below_low_watermark(9), "1 used + 9 incoming == low");
        assert!(!m.below_low_watermark(10));
        assert!(!m.above_high_watermark());
    }

    #[test]
    fn contention_counters_observe_try_lock_failures() {
        use std::sync::atomic::AtomicBool;
        let m = BlockManager::new(64);
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = m.clone();
        let stop2 = Arc::clone(&stop);
        let t = std::thread::spawn(move || {
            let s = m2.register();
            while !stop2.load(Relaxed) {
                let p = m2.alloc(s).unwrap();
                m2.release(s, p);
            }
            m2.unregister(s);
        });
        let s = m.register();
        for _ in 0..20_000 {
            let p = m.alloc(s).unwrap();
            m.release(s, p);
        }
        stop.store(true, Relaxed);
        t.join().unwrap();
        let st = m.stats();
        assert!(st.lock_acquisitions > 0);
        assert!(
            st.contended_acquisitions <= st.lock_acquisitions,
            "contended is a subset of total"
        );
    }
}
