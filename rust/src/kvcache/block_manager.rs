//! Process-wide physical block arena with refcounted sharing and a
//! content-hash prefix index.
//!
//! One `BlockManager` owns every physical KV slot in the server; each live
//! sequence ([`crate::kvcache::SeqCache`]) registers for a [`SeqId`] and
//! allocates/releases blocks through the shared handle. This replaces the
//! old per-sequence `BlockPool`: capacity is a single real number the
//! scheduler reads in O(1) (`used()` / `free_count()`), not an estimate
//! summed over running sequences, which is what makes admission gating and
//! preemption-under-memory-pressure expressible at all.
//!
//! **Sharing.** A slot can be held by several sequences at once: `alloc`
//! creates a private (refcount 1) claim, [`BlockManager::acquire_shared`]
//! adds another holder to a slot found through the prefix index, and
//! [`BlockManager::release`] drops one holder's claim — the slot returns
//! to the free list only when the LAST holder releases it (refcount 0).
//! `used()`/watermarks count a shared slot ONCE, which is the whole
//! memory win of prefix caching.
//!
//! **Prefix index.** [`BlockManager::publish`] maps a chained content hash
//! (see `seq_cache::prefix_block_hashes`) to a slot holding a FULL prompt
//! block. Later prefills walk their own chain through
//! [`BlockManager::acquire_shared`] and map the hits instead of
//! re-materializing them. An index entry is removed when its slot is freed
//! (refcount 0) or when the sole holder is about to mutate the content in
//! place ([`BlockManager::unpublish_slot`], driven by
//! `SeqCache::make_private`). Shared (refcount > 1) slots are FROZEN:
//! holders must copy-on-write before any in-place mutation, so an index
//! entry always describes the live content of its slot.
//!
//! Per-slot holder lists keep double frees and foreign frees (sequence A
//! releasing a claim it does not hold) hard errors in every build.
//!
//! The handle is `Clone + Send + Sync` (an `Arc<Mutex<..>>`): the lock is
//! only taken on block allocation/release/publish — once every `page_size`
//! decode steps per sequence — never on the per-token metadata path
//! (blocks that never touched the prefix index skip it entirely, see
//! `Block::prefix_tracked`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// Identity of a registered sequence within one arena. Obtained from
/// [`BlockManager::register`]; ids are recycled after `unregister`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeqId(u32);

impl SeqId {
    pub fn raw(self) -> u32 {
        self.0
    }
}

/// Arena-wide accounting snapshot (all O(1) counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    pub capacity: usize,
    pub used: usize,
    /// High-water mark of simultaneously allocated blocks — the real
    /// physical-memory footprint of the whole server. A shared slot
    /// counts once, so prefix caching lowers this directly.
    pub peak_used: usize,
    /// Private allocations (`alloc`); shared acquisitions are counted in
    /// `prefix_hits` instead.
    pub allocs: u64,
    /// Holder releases (both private frees and shared refcount drops).
    pub frees: u64,
    pub grows: u64,
    /// Live registered sequences.
    pub sequences: usize,
    /// Successful `acquire_shared` calls — prompt blocks served from the
    /// prefix index instead of allocated.
    pub prefix_hits: u64,
    /// Slots currently published in the prefix index.
    pub published_blocks: usize,
}

#[derive(Debug)]
struct Inner {
    /// LIFO free list; initialized in reverse so slot 0 is handed out
    /// first (keeps the single-tenant layout identity tests rely on).
    free: Vec<usize>,
    /// `holders[phys]`: raw `SeqId`s holding a claim on the slot, empty =
    /// free. Refcount == `holders[phys].len()`; almost always 0 or 1, so
    /// the membership scans below are effectively O(1).
    holders: Vec<Vec<u32>>,
    /// Claims held per registered id (indexed by raw id).
    owned: Vec<usize>,
    registered: Vec<bool>,
    free_ids: Vec<u32>,
    /// Content hash -> slot, full prompt blocks only (the prefix index).
    prefix: HashMap<u64, usize>,
    /// `slot_hash[phys]`: the hash this slot is published under, if any.
    slot_hash: Vec<Option<u64>>,
    /// Bumped on every prefix-index mutation (publish or unpublish).
    /// Admission-time claim estimates are memoized against this: an
    /// unchanged epoch means `count_leading_hits` would return the same
    /// answer, so a gated admission retry can skip recomputing its
    /// O(prompt) claim (see `scheduler::backend::ClaimMemo`).
    prefix_epoch: u64,
    peak_used: usize,
    allocs: u64,
    frees: u64,
    grows: u64,
    prefix_hits: u64,
    /// Admission watermark as a fraction of capacity (see
    /// [`BlockManager::set_watermarks`]). Stored as fractions so `grow`
    /// rescales the block thresholds automatically.
    low_frac: f64,
    /// Preemption watermark as a fraction of capacity.
    high_frac: f64,
}

impl Inner {
    fn capacity(&self) -> usize {
        self.holders.len()
    }

    fn used(&self) -> usize {
        self.capacity() - self.free.len()
    }

    fn low_blocks(&self) -> usize {
        (self.low_frac * self.capacity() as f64).floor() as usize
    }

    fn high_blocks(&self) -> usize {
        (self.high_frac * self.capacity() as f64).floor() as usize
    }

    /// Remove the index entry of `phys`, if any. Idempotent.
    fn unpublish(&mut self, phys: usize) {
        if let Some(h) = self.slot_hash[phys].take() {
            self.prefix.remove(&h);
            self.prefix_epoch += 1;
        }
    }

    /// Drop one claim of `seq` on `phys`; frees (and unpublishes) the slot
    /// when the last claim goes. Returns an error message on a violation.
    fn drop_claim(&mut self, seq: u32, phys: usize) -> Result<(), String> {
        if phys >= self.holders.len() {
            return Err(format!("release of out-of-range block {phys}"));
        }
        if self.holders[phys].is_empty() {
            return Err(format!("double free of block {phys}"));
        }
        let Some(pos) = self.holders[phys].iter().position(|&h| h == seq) else {
            return Err(format!(
                "foreign free: seq {seq} releasing block {phys} held by seqs {:?}",
                self.holders[phys]
            ));
        };
        self.holders[phys].swap_remove(pos);
        self.owned[seq as usize] -= 1;
        self.frees += 1;
        if self.holders[phys].is_empty() {
            self.unpublish(phys);
            self.free.push(phys);
        }
        Ok(())
    }
}

/// Cloneable handle to the shared arena.
#[derive(Debug, Clone)]
pub struct BlockManager(Arc<Mutex<Inner>>);

impl BlockManager {
    pub fn new(capacity_blocks: usize) -> Self {
        BlockManager(Arc::new(Mutex::new(Inner {
            free: (0..capacity_blocks).rev().collect(),
            holders: (0..capacity_blocks).map(|_| Vec::new()).collect(),
            owned: Vec::new(),
            registered: Vec::new(),
            free_ids: Vec::new(),
            prefix: HashMap::new(),
            slot_hash: vec![None; capacity_blocks],
            prefix_epoch: 0,
            peak_used: 0,
            allocs: 0,
            frees: 0,
            grows: 0,
            prefix_hits: 0,
            // Default watermarks sit at capacity: admission gates on raw
            // physical headroom and proactive preemption never fires —
            // the historical hard-capacity semantics.
            low_frac: 1.0,
            high_frac: 1.0,
        })))
    }

    /// Lock helper. Ignores poisoning: the arena's invariants are restored
    /// before any panic below, and `SeqCache::drop` must still be able to
    /// return blocks while unwinding from an unrelated panic.
    fn inner(&self) -> MutexGuard<'_, Inner> {
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register a new sequence and return its arena identity.
    pub fn register(&self) -> SeqId {
        let mut g = self.inner();
        let id = match g.free_ids.pop() {
            Some(id) => id,
            None => {
                let id = g.owned.len() as u32;
                g.owned.push(0);
                g.registered.push(false);
                id
            }
        };
        g.owned[id as usize] = 0;
        g.registered[id as usize] = true;
        SeqId(id)
    }

    /// Drop a sequence: its id is recycled, and any claim it still holds
    /// is released. Callers that know their slots (e.g. `SeqCache::drop`)
    /// release them first so the O(capacity) holder scan below only runs
    /// as a leak-proofing fallback.
    pub fn unregister(&self, seq: SeqId) {
        let mut g = self.inner();
        let id = seq.0 as usize;
        if id >= g.registered.len() || !g.registered[id] {
            return; // already gone — unregister is idempotent for Drop
        }
        if g.owned[id] > 0 {
            for phys in 0..g.holders.len() {
                if g.holders[phys].contains(&seq.0) {
                    g.drop_claim(seq.0, phys).expect("holder just found");
                }
            }
        }
        g.registered[id] = false;
        g.free_ids.push(seq.0);
    }

    /// Allocate one PRIVATE block for `seq` (refcount 1). `None` when the
    /// arena is dry (the scheduler's preemption trigger).
    pub fn alloc(&self, seq: SeqId) -> Option<usize> {
        let mut g = self.inner();
        debug_assert!(g.registered[seq.0 as usize], "alloc on unregistered seq");
        let phys = g.free.pop()?;
        debug_assert!(g.holders[phys].is_empty() && g.slot_hash[phys].is_none());
        g.holders[phys].push(seq.0);
        g.owned[seq.0 as usize] += 1;
        g.allocs += 1;
        let used = g.used();
        g.peak_used = g.peak_used.max(used);
        Some(phys)
    }

    /// Look up `hash` in the prefix index and, on a hit, add `seq` as a
    /// holder of the published slot (refcount + 1; `used()` unchanged —
    /// that is the memory saving). `None` on a miss, or when `seq` already
    /// holds the slot (a sequence maps each physical page at most once).
    pub fn acquire_shared(&self, seq: SeqId, hash: u64) -> Option<usize> {
        let mut g = self.inner();
        debug_assert!(g.registered[seq.0 as usize], "acquire on unregistered seq");
        let phys = *g.prefix.get(&hash)?;
        if g.holders[phys].contains(&seq.0) {
            return None;
        }
        g.holders[phys].push(seq.0);
        g.owned[seq.0 as usize] += 1;
        g.prefix_hits += 1;
        Some(phys)
    }

    /// Publish the content hash of a FULL block held by `seq` into the
    /// prefix index, making it shareable. First publisher wins: returns
    /// `false` (and indexes nothing) when the hash is already mapped, when
    /// the slot is already published under another hash, or when `seq`
    /// does not hold the slot.
    pub fn publish(&self, seq: SeqId, phys: usize, hash: u64) -> bool {
        let mut g = self.inner();
        if phys >= g.holders.len() || !g.holders[phys].contains(&seq.0) {
            return false;
        }
        if g.slot_hash[phys].is_some() || g.prefix.contains_key(&hash) {
            return false;
        }
        g.prefix.insert(hash, phys);
        g.slot_hash[phys] = Some(hash);
        g.prefix_epoch += 1;
        true
    }

    /// Remove `phys` from the prefix index (sole holder about to mutate
    /// the content in place). Idempotent; no-op for unpublished slots.
    pub fn unpublish_slot(&self, phys: usize) {
        let mut g = self.inner();
        if phys < g.holders.len() {
            g.unpublish(phys);
        }
    }

    /// Current holder count of `phys` (0 = free). A result > 1 means the
    /// slot is shared and must be copied-on-write before in-place writes.
    pub fn refcount(&self, phys: usize) -> usize {
        let g = self.inner();
        g.holders.get(phys).map_or(0, |h| h.len())
    }

    /// Generation counter of the prefix index: changes exactly when a
    /// publish or unpublish changes what `count_leading_hits` could
    /// answer. The admission claim-memoization key.
    pub fn prefix_epoch(&self) -> u64 {
        self.inner().prefix_epoch
    }

    /// True when `phys` is currently published in the prefix index.
    pub fn is_published(&self, phys: usize) -> bool {
        let g = self.inner();
        phys < g.slot_hash.len() && g.slot_hash[phys].is_some()
    }

    /// How many LEADING entries of `hashes` are currently published — the
    /// admission-time estimate of how many prompt blocks a prefill would
    /// map from the index instead of allocating. Read-only: acquires
    /// nothing (the walk in `try_load_prefill_cached` does the claiming).
    pub fn count_leading_hits(&self, hashes: &[u64]) -> usize {
        let g = self.inner();
        hashes.iter().take_while(|h| g.prefix.contains_key(h)).count()
    }

    /// Release one claim of `seq` on `phys`: the slot returns to the free
    /// list (and leaves the prefix index) only when the LAST claim goes.
    /// Panics on double free (slot already free) and on foreign free
    /// (`seq` holds no claim on the slot) — both are memory-safety bugs in
    /// the caller, checked in O(holders) in every build.
    pub fn release(&self, seq: SeqId, phys: usize) {
        let mut g = self.inner();
        if let Err(msg) = g.drop_claim(seq.0, phys) {
            drop(g); // release the lock before unwinding
            panic!("{msg}");
        }
    }

    /// Extend the arena to `new_capacity` slots (device memory growth).
    pub fn grow(&self, new_capacity: usize) {
        let mut g = self.inner();
        let old = g.capacity();
        assert!(new_capacity >= old, "arena cannot shrink");
        for p in (old..new_capacity).rev() {
            g.free.push(p);
        }
        g.holders.resize_with(new_capacity, Vec::new);
        g.slot_hash.resize(new_capacity, None);
        g.grows += 1;
    }

    /// Configure the admission/preemption hysteresis band as fractions of
    /// capacity (rescaled automatically on `grow`). The scheduler admits a
    /// sequence only while usage would stay at or below the LOW mark and
    /// preempts once usage exceeds the HIGH mark; the gap between them
    /// absorbs decode-time growth so optimistic admission cannot thrash.
    pub fn set_watermarks(&self, low: f64, high: f64) {
        assert!(
            low > 0.0 && low <= high && high <= 1.0,
            "watermarks must satisfy 0 < low <= high <= 1 (got {low}, {high})"
        );
        let mut g = self.inner();
        g.low_frac = low;
        g.high_frac = high;
    }

    /// `(low, high)` watermarks in blocks at the current capacity.
    pub fn watermark_blocks(&self) -> (usize, usize) {
        let g = self.inner();
        (g.low_blocks(), g.high_blocks())
    }

    /// True when allocating `incoming` more blocks keeps usage at or below
    /// the low watermark — the scheduler's admission gate. With default
    /// watermarks (1.0) this degenerates to "fits physical capacity".
    pub fn below_low_watermark(&self, incoming: usize) -> bool {
        let g = self.inner();
        g.used() + incoming <= g.low_blocks()
    }

    /// True when usage exceeds the high watermark — the scheduler's
    /// proactive preemption trigger (reclaims the optimism the low-mark
    /// admission gate extends). Never true with default watermarks.
    pub fn above_high_watermark(&self) -> bool {
        let g = self.inner();
        g.used() > g.high_blocks()
    }

    pub fn capacity(&self) -> usize {
        self.inner().capacity()
    }

    pub fn free_count(&self) -> usize {
        self.inner().free.len()
    }

    pub fn used(&self) -> usize {
        self.inner().used()
    }

    /// Claims currently held by `seq` (a shared slot counts one claim per
    /// holder).
    pub fn owned_by(&self, seq: SeqId) -> usize {
        let g = self.inner();
        g.owned.get(seq.0 as usize).copied().unwrap_or(0)
    }

    pub fn stats(&self) -> ArenaStats {
        let g = self.inner();
        ArenaStats {
            capacity: g.capacity(),
            used: g.used(),
            peak_used: g.peak_used,
            allocs: g.allocs,
            frees: g.frees,
            grows: g.grows,
            sequences: g.registered.iter().filter(|&&r| r).count(),
            prefix_hits: g.prefix_hits,
            published_blocks: g.prefix.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let m = BlockManager::new(3);
        let s = m.register();
        assert_eq!(m.alloc(s), Some(0));
        assert_eq!(m.alloc(s), Some(1));
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), None);
        assert_eq!(m.used(), 3);
        m.release(s, 1);
        assert_eq!(m.alloc(s), Some(1), "LIFO reuse of the freed slot");
        assert_eq!(m.stats().peak_used, 3);
    }

    #[test]
    fn per_seq_ownership_is_tracked() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let p0 = m.alloc(a).unwrap();
        let _p1 = m.alloc(b).unwrap();
        let _p2 = m.alloc(b).unwrap();
        assert_eq!(m.owned_by(a), 1);
        assert_eq!(m.owned_by(b), 2);
        assert_eq!(m.used(), 3);
        m.release(a, p0);
        assert_eq!(m.owned_by(a), 0);
        assert_eq!(m.free_count(), 2);
    }

    #[test]
    fn unregister_releases_everything() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        m.alloc(a).unwrap();
        m.alloc(a).unwrap();
        m.alloc(b).unwrap();
        m.unregister(a);
        assert_eq!(m.used(), 1, "a's blocks returned to the arena");
        assert_eq!(m.stats().sequences, 1);
        m.unregister(a); // idempotent
        assert_eq!(m.used(), 1);
    }

    #[test]
    fn grow_extends_capacity() {
        let m = BlockManager::new(2);
        let s = m.register();
        m.alloc(s).unwrap();
        m.alloc(s).unwrap();
        assert_eq!(m.alloc(s), None);
        m.grow(4);
        assert_eq!(m.capacity(), 4);
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), Some(3));
        assert_eq!(m.stats().grows, 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let m = BlockManager::new(2);
        let s = m.register();
        let p = m.alloc(s).unwrap();
        m.release(s, p);
        m.release(s, p);
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn foreign_free_panics() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        m.release(b, p);
    }

    #[test]
    fn shared_slot_frees_only_at_refcount_zero() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        assert!(m.publish(a, p, 0xfeed));
        assert_eq!(m.acquire_shared(b, 0xfeed), Some(p));
        assert_eq!(m.refcount(p), 2);
        assert_eq!(m.used(), 1, "a shared slot counts once");
        assert_eq!(m.owned_by(a), 1);
        assert_eq!(m.owned_by(b), 1);
        m.release(a, p);
        assert_eq!(m.refcount(p), 1, "b's claim keeps the slot alive");
        assert_eq!(m.used(), 1);
        assert!(m.is_published(p), "surviving holders keep the index entry");
        m.release(b, p);
        assert_eq!(m.refcount(p), 0);
        assert_eq!(m.used(), 0);
        assert!(!m.is_published(p), "freeing the slot removes it from the index");
        assert_eq!(m.acquire_shared(b, 0xfeed), None, "stale hash no longer hits");
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn released_sharer_cannot_release_twice() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        assert!(m.publish(a, p, 7));
        assert_eq!(m.acquire_shared(b, 7), Some(p));
        m.release(b, p);
        m.release(b, p); // a still holds the slot: this is a foreign free
    }

    #[test]
    fn publish_is_first_wins_and_holder_only() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let pa = m.alloc(a).unwrap();
        let pb = m.alloc(b).unwrap();
        assert!(!m.publish(b, pa, 1), "only a holder may publish a slot");
        assert!(m.publish(a, pa, 1));
        assert!(!m.publish(b, pb, 1), "hash already mapped: first publisher wins");
        assert!(!m.publish(a, pa, 2), "slot already published under another hash");
        assert_eq!(m.stats().published_blocks, 1);
        m.unpublish_slot(pa);
        assert!(!m.is_published(pa));
        assert_eq!(m.acquire_shared(b, 1), None);
        assert_eq!(m.refcount(pa), 1, "unpublish does not release the holder");
    }

    #[test]
    fn count_leading_hits_walks_the_chain() {
        let m = BlockManager::new(4);
        let a = m.register();
        for (i, h) in [10u64, 11, 12].iter().enumerate() {
            let p = m.alloc(a).unwrap();
            assert_eq!(p, i);
            assert!(m.publish(a, p, *h));
        }
        assert_eq!(m.count_leading_hits(&[10, 11, 12]), 3);
        assert_eq!(m.count_leading_hits(&[10, 99, 12]), 1, "stops at the first miss");
        assert_eq!(m.count_leading_hits(&[99]), 0);
        assert_eq!(m.count_leading_hits(&[]), 0);
        assert_eq!(m.stats().prefix_hits, 0, "counting acquires nothing");
    }

    #[test]
    fn unregister_drops_shared_claims_without_freeing_live_slots() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        assert!(m.publish(a, p, 3));
        assert_eq!(m.acquire_shared(b, 3), Some(p));
        m.alloc(b).unwrap();
        m.unregister(b);
        assert_eq!(m.refcount(p), 1, "a's claim survives b's unregister");
        assert_eq!(m.used(), 1, "b's private block was freed");
        assert!(m.is_published(p));
    }

    #[test]
    fn default_watermarks_are_hard_capacity() {
        let m = BlockManager::new(10);
        assert_eq!(m.watermark_blocks(), (10, 10));
        let s = m.register();
        for _ in 0..10 {
            m.alloc(s).unwrap();
        }
        assert!(!m.above_high_watermark(), "high mark at capacity never trips");
        assert!(m.below_low_watermark(0));
        assert!(!m.below_low_watermark(1));
    }

    #[test]
    fn watermark_band_gates_and_trips() {
        let m = BlockManager::new(20);
        m.set_watermarks(0.5, 0.75); // low = 10 blocks, high = 15 blocks
        assert_eq!(m.watermark_blocks(), (10, 15));
        let s = m.register();
        for _ in 0..8 {
            m.alloc(s).unwrap();
        }
        assert!(m.below_low_watermark(2), "8 + 2 == low");
        assert!(!m.below_low_watermark(3), "8 + 3 crosses the low mark");
        assert!(!m.above_high_watermark());
        for _ in 0..8 {
            m.alloc(s).unwrap();
        }
        assert!(m.above_high_watermark(), "16 > high mark 15");
    }

    #[test]
    fn watermarks_rescale_on_grow() {
        let m = BlockManager::new(10);
        m.set_watermarks(0.5, 0.8);
        assert_eq!(m.watermark_blocks(), (5, 8));
        m.grow(20);
        assert_eq!(m.watermark_blocks(), (10, 16), "fractions track capacity");
    }

    #[test]
    #[should_panic(expected = "watermarks must satisfy")]
    fn inverted_watermarks_rejected() {
        BlockManager::new(4).set_watermarks(0.9, 0.5);
    }

    #[test]
    fn prefix_epoch_tracks_index_mutations_only() {
        let m = BlockManager::new(4);
        let a = m.register();
        let e0 = m.prefix_epoch();
        let p = m.alloc(a).unwrap();
        assert_eq!(m.prefix_epoch(), e0, "private alloc leaves the index alone");
        assert!(m.publish(a, p, 42));
        let e1 = m.prefix_epoch();
        assert!(e1 > e0, "publish bumps the epoch");
        assert!(!m.publish(a, p, 43), "already published");
        assert_eq!(m.prefix_epoch(), e1, "failed publish does not bump");
        m.unpublish_slot(p);
        let e2 = m.prefix_epoch();
        assert!(e2 > e1, "unpublish bumps the epoch");
        m.unpublish_slot(p); // idempotent: nothing to remove
        assert_eq!(m.prefix_epoch(), e2);
        let q = m.alloc(a).unwrap();
        assert!(m.publish(a, q, 44));
        let e3 = m.prefix_epoch();
        m.release(a, q);
        assert!(m.prefix_epoch() > e3, "freeing a published slot unpublishes");
    }

    #[test]
    fn id_recycling() {
        let m = BlockManager::new(2);
        let a = m.register();
        let raw = a.raw();
        m.unregister(a);
        let b = m.register();
        assert_eq!(b.raw(), raw, "freed id is recycled");
    }
}
