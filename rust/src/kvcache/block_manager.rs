//! Process-wide physical block arena.
//!
//! One `BlockManager` owns every physical KV slot in the server; each live
//! sequence ([`crate::kvcache::SeqCache`]) registers for a [`SeqId`] and
//! allocates/releases blocks through the shared handle. This replaces the
//! old per-sequence `BlockPool`: capacity is a single real number the
//! scheduler reads in O(1) (`used()` / `free_count()`), not an estimate
//! summed over running sequences, which is what makes admission gating and
//! preemption-under-memory-pressure expressible at all.
//!
//! Ownership is tracked per slot (`owner[phys]`), so double frees and
//! foreign frees (sequence A releasing a block held by sequence B) are hard
//! errors in every build, in O(1) — the old pool only caught double frees
//! with a `debug_assert!` over an O(n) `contains` scan.
//!
//! The handle is `Clone + Send + Sync` (an `Arc<Mutex<..>>`): the lock is
//! only taken on block allocation/release — once every `page_size` decode
//! steps per sequence — never on the per-token metadata path.

use std::sync::{Arc, Mutex, MutexGuard};

/// Sentinel owner value for a free slot.
const NO_OWNER: u32 = u32::MAX;

/// Identity of a registered sequence within one arena. Obtained from
/// [`BlockManager::register`]; ids are recycled after `unregister`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeqId(u32);

impl SeqId {
    pub fn raw(self) -> u32 {
        self.0
    }
}

/// Arena-wide accounting snapshot (all O(1) counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    pub capacity: usize,
    pub used: usize,
    /// High-water mark of simultaneously allocated blocks — the real
    /// physical-memory footprint of the whole server.
    pub peak_used: usize,
    pub allocs: u64,
    pub frees: u64,
    pub grows: u64,
    /// Live registered sequences.
    pub sequences: usize,
}

#[derive(Debug)]
struct Inner {
    /// LIFO free list; initialized in reverse so slot 0 is handed out
    /// first (keeps the single-tenant layout identity tests rely on).
    free: Vec<usize>,
    /// `owner[phys]`: raw `SeqId` holding the slot, or `NO_OWNER`.
    owner: Vec<u32>,
    /// Blocks held per registered id (indexed by raw id).
    owned: Vec<usize>,
    registered: Vec<bool>,
    free_ids: Vec<u32>,
    peak_used: usize,
    allocs: u64,
    frees: u64,
    grows: u64,
    /// Admission watermark as a fraction of capacity (see
    /// [`BlockManager::set_watermarks`]). Stored as fractions so `grow`
    /// rescales the block thresholds automatically.
    low_frac: f64,
    /// Preemption watermark as a fraction of capacity.
    high_frac: f64,
}

impl Inner {
    fn capacity(&self) -> usize {
        self.owner.len()
    }

    fn used(&self) -> usize {
        self.capacity() - self.free.len()
    }

    fn low_blocks(&self) -> usize {
        (self.low_frac * self.capacity() as f64).floor() as usize
    }

    fn high_blocks(&self) -> usize {
        (self.high_frac * self.capacity() as f64).floor() as usize
    }
}

/// Cloneable handle to the shared arena.
#[derive(Debug, Clone)]
pub struct BlockManager(Arc<Mutex<Inner>>);

impl BlockManager {
    pub fn new(capacity_blocks: usize) -> Self {
        BlockManager(Arc::new(Mutex::new(Inner {
            free: (0..capacity_blocks).rev().collect(),
            owner: vec![NO_OWNER; capacity_blocks],
            owned: Vec::new(),
            registered: Vec::new(),
            free_ids: Vec::new(),
            peak_used: 0,
            allocs: 0,
            frees: 0,
            grows: 0,
            // Default watermarks sit at capacity: admission gates on raw
            // physical headroom and proactive preemption never fires —
            // the historical hard-capacity semantics.
            low_frac: 1.0,
            high_frac: 1.0,
        })))
    }

    /// Lock helper. Ignores poisoning: the arena's invariants are restored
    /// before any panic below, and `SeqCache::drop` must still be able to
    /// return blocks while unwinding from an unrelated panic.
    fn inner(&self) -> MutexGuard<'_, Inner> {
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register a new sequence and return its arena identity.
    pub fn register(&self) -> SeqId {
        let mut g = self.inner();
        let id = match g.free_ids.pop() {
            Some(id) => id,
            None => {
                let id = g.owned.len() as u32;
                g.owned.push(0);
                g.registered.push(false);
                id
            }
        };
        g.owned[id as usize] = 0;
        g.registered[id as usize] = true;
        SeqId(id)
    }

    /// Drop a sequence: its id is recycled, and any block it still holds
    /// returns to the free list. Callers that know their slots (e.g.
    /// `SeqCache::drop`) release them first so the O(capacity) ownership
    /// scan below only runs as a leak-proofing fallback.
    pub fn unregister(&self, seq: SeqId) {
        let mut g = self.inner();
        let id = seq.0 as usize;
        if id >= g.registered.len() || !g.registered[id] {
            return; // already gone — unregister is idempotent for Drop
        }
        if g.owned[id] > 0 {
            for phys in 0..g.owner.len() {
                if g.owner[phys] == seq.0 {
                    g.owner[phys] = NO_OWNER;
                    g.free.push(phys);
                    g.frees += 1;
                }
            }
            g.owned[id] = 0;
        }
        g.registered[id] = false;
        g.free_ids.push(seq.0);
    }

    /// Allocate one block for `seq`. `None` when the arena is dry (the
    /// scheduler's preemption trigger).
    pub fn alloc(&self, seq: SeqId) -> Option<usize> {
        let mut g = self.inner();
        debug_assert!(g.registered[seq.0 as usize], "alloc on unregistered seq");
        let phys = g.free.pop()?;
        g.owner[phys] = seq.0;
        g.owned[seq.0 as usize] += 1;
        g.allocs += 1;
        let used = g.used();
        g.peak_used = g.peak_used.max(used);
        Some(phys)
    }

    /// Return one block. Panics on double free (slot already free) and on
    /// foreign free (slot held by another sequence) — both are memory-
    /// safety bugs in the caller, checked in O(1) in every build.
    pub fn release(&self, seq: SeqId, phys: usize) {
        let mut g = self.inner();
        let violation = if phys >= g.owner.len() {
            Some(format!("release of out-of-range block {phys}"))
        } else if g.owner[phys] == NO_OWNER {
            Some(format!("double free of block {phys}"))
        } else if g.owner[phys] != seq.0 {
            Some(format!(
                "foreign free: seq {} releasing block {phys} owned by seq {}",
                seq.0, g.owner[phys]
            ))
        } else {
            None
        };
        match violation {
            None => {
                g.owner[phys] = NO_OWNER;
                g.owned[seq.0 as usize] -= 1;
                g.free.push(phys);
                g.frees += 1;
            }
            Some(msg) => {
                drop(g); // release the lock before unwinding
                panic!("{msg}");
            }
        }
    }

    /// Extend the arena to `new_capacity` slots (device memory growth).
    pub fn grow(&self, new_capacity: usize) {
        let mut g = self.inner();
        let old = g.capacity();
        assert!(new_capacity >= old, "arena cannot shrink");
        for p in (old..new_capacity).rev() {
            g.free.push(p);
        }
        g.owner.resize(new_capacity, NO_OWNER);
        g.grows += 1;
    }

    /// Configure the admission/preemption hysteresis band as fractions of
    /// capacity (rescaled automatically on `grow`). The scheduler admits a
    /// sequence only while usage would stay at or below the LOW mark and
    /// preempts once usage exceeds the HIGH mark; the gap between them
    /// absorbs decode-time growth so optimistic admission cannot thrash.
    pub fn set_watermarks(&self, low: f64, high: f64) {
        assert!(
            low > 0.0 && low <= high && high <= 1.0,
            "watermarks must satisfy 0 < low <= high <= 1 (got {low}, {high})"
        );
        let mut g = self.inner();
        g.low_frac = low;
        g.high_frac = high;
    }

    /// `(low, high)` watermarks in blocks at the current capacity.
    pub fn watermark_blocks(&self) -> (usize, usize) {
        let g = self.inner();
        (g.low_blocks(), g.high_blocks())
    }

    /// True when allocating `incoming` more blocks keeps usage at or below
    /// the low watermark — the scheduler's admission gate. With default
    /// watermarks (1.0) this degenerates to "fits physical capacity".
    pub fn below_low_watermark(&self, incoming: usize) -> bool {
        let g = self.inner();
        g.used() + incoming <= g.low_blocks()
    }

    /// True when usage exceeds the high watermark — the scheduler's
    /// proactive preemption trigger (reclaims the optimism the low-mark
    /// admission gate extends). Never true with default watermarks.
    pub fn above_high_watermark(&self) -> bool {
        let g = self.inner();
        g.used() > g.high_blocks()
    }

    pub fn capacity(&self) -> usize {
        self.inner().capacity()
    }

    pub fn free_count(&self) -> usize {
        self.inner().free.len()
    }

    pub fn used(&self) -> usize {
        self.inner().used()
    }

    /// Blocks currently held by `seq`.
    pub fn owned_by(&self, seq: SeqId) -> usize {
        let g = self.inner();
        g.owned.get(seq.0 as usize).copied().unwrap_or(0)
    }

    pub fn stats(&self) -> ArenaStats {
        let g = self.inner();
        ArenaStats {
            capacity: g.capacity(),
            used: g.used(),
            peak_used: g.peak_used,
            allocs: g.allocs,
            frees: g.frees,
            grows: g.grows,
            sequences: g.registered.iter().filter(|&&r| r).count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let m = BlockManager::new(3);
        let s = m.register();
        assert_eq!(m.alloc(s), Some(0));
        assert_eq!(m.alloc(s), Some(1));
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), None);
        assert_eq!(m.used(), 3);
        m.release(s, 1);
        assert_eq!(m.alloc(s), Some(1), "LIFO reuse of the freed slot");
        assert_eq!(m.stats().peak_used, 3);
    }

    #[test]
    fn per_seq_ownership_is_tracked() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let p0 = m.alloc(a).unwrap();
        let _p1 = m.alloc(b).unwrap();
        let _p2 = m.alloc(b).unwrap();
        assert_eq!(m.owned_by(a), 1);
        assert_eq!(m.owned_by(b), 2);
        assert_eq!(m.used(), 3);
        m.release(a, p0);
        assert_eq!(m.owned_by(a), 0);
        assert_eq!(m.free_count(), 2);
    }

    #[test]
    fn unregister_releases_everything() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        m.alloc(a).unwrap();
        m.alloc(a).unwrap();
        m.alloc(b).unwrap();
        m.unregister(a);
        assert_eq!(m.used(), 1, "a's blocks returned to the arena");
        assert_eq!(m.stats().sequences, 1);
        m.unregister(a); // idempotent
        assert_eq!(m.used(), 1);
    }

    #[test]
    fn grow_extends_capacity() {
        let m = BlockManager::new(2);
        let s = m.register();
        m.alloc(s).unwrap();
        m.alloc(s).unwrap();
        assert_eq!(m.alloc(s), None);
        m.grow(4);
        assert_eq!(m.capacity(), 4);
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), Some(3));
        assert_eq!(m.stats().grows, 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let m = BlockManager::new(2);
        let s = m.register();
        let p = m.alloc(s).unwrap();
        m.release(s, p);
        m.release(s, p);
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn foreign_free_panics() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        m.release(b, p);
    }

    #[test]
    fn default_watermarks_are_hard_capacity() {
        let m = BlockManager::new(10);
        assert_eq!(m.watermark_blocks(), (10, 10));
        let s = m.register();
        for _ in 0..10 {
            m.alloc(s).unwrap();
        }
        assert!(!m.above_high_watermark(), "high mark at capacity never trips");
        assert!(m.below_low_watermark(0));
        assert!(!m.below_low_watermark(1));
    }

    #[test]
    fn watermark_band_gates_and_trips() {
        let m = BlockManager::new(20);
        m.set_watermarks(0.5, 0.75); // low = 10 blocks, high = 15 blocks
        assert_eq!(m.watermark_blocks(), (10, 15));
        let s = m.register();
        for _ in 0..8 {
            m.alloc(s).unwrap();
        }
        assert!(m.below_low_watermark(2), "8 + 2 == low");
        assert!(!m.below_low_watermark(3), "8 + 3 crosses the low mark");
        assert!(!m.above_high_watermark());
        for _ in 0..8 {
            m.alloc(s).unwrap();
        }
        assert!(m.above_high_watermark(), "16 > high mark 15");
    }

    #[test]
    fn watermarks_rescale_on_grow() {
        let m = BlockManager::new(10);
        m.set_watermarks(0.5, 0.8);
        assert_eq!(m.watermark_blocks(), (5, 8));
        m.grow(20);
        assert_eq!(m.watermark_blocks(), (10, 16), "fractions track capacity");
    }

    #[test]
    #[should_panic(expected = "watermarks must satisfy")]
    fn inverted_watermarks_rejected() {
        BlockManager::new(4).set_watermarks(0.9, 0.5);
    }

    #[test]
    fn id_recycling() {
        let m = BlockManager::new(2);
        let a = m.register();
        let raw = a.raw();
        m.unregister(a);
        let b = m.register();
        assert_eq!(b.raw(), raw, "freed id is recycled");
    }
}
