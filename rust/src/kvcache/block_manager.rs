//! Process-wide physical block arena.
//!
//! One `BlockManager` owns every physical KV slot in the server; each live
//! sequence ([`crate::kvcache::SeqCache`]) registers for a [`SeqId`] and
//! allocates/releases blocks through the shared handle. This replaces the
//! old per-sequence `BlockPool`: capacity is a single real number the
//! scheduler reads in O(1) (`used()` / `free_count()`), not an estimate
//! summed over running sequences, which is what makes admission gating and
//! preemption-under-memory-pressure expressible at all.
//!
//! Ownership is tracked per slot (`owner[phys]`), so double frees and
//! foreign frees (sequence A releasing a block held by sequence B) are hard
//! errors in every build, in O(1) — the old pool only caught double frees
//! with a `debug_assert!` over an O(n) `contains` scan.
//!
//! The handle is `Clone + Send + Sync` (an `Arc<Mutex<..>>`): the lock is
//! only taken on block allocation/release — once every `page_size` decode
//! steps per sequence — never on the per-token metadata path.

use std::sync::{Arc, Mutex, MutexGuard};

/// Sentinel owner value for a free slot.
const NO_OWNER: u32 = u32::MAX;

/// Identity of a registered sequence within one arena. Obtained from
/// [`BlockManager::register`]; ids are recycled after `unregister`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeqId(u32);

impl SeqId {
    pub fn raw(self) -> u32 {
        self.0
    }
}

/// Arena-wide accounting snapshot (all O(1) counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    pub capacity: usize,
    pub used: usize,
    /// High-water mark of simultaneously allocated blocks — the real
    /// physical-memory footprint of the whole server.
    pub peak_used: usize,
    pub allocs: u64,
    pub frees: u64,
    pub grows: u64,
    /// Live registered sequences.
    pub sequences: usize,
}

#[derive(Debug)]
struct Inner {
    /// LIFO free list; initialized in reverse so slot 0 is handed out
    /// first (keeps the single-tenant layout identity tests rely on).
    free: Vec<usize>,
    /// `owner[phys]`: raw `SeqId` holding the slot, or `NO_OWNER`.
    owner: Vec<u32>,
    /// Blocks held per registered id (indexed by raw id).
    owned: Vec<usize>,
    registered: Vec<bool>,
    free_ids: Vec<u32>,
    peak_used: usize,
    allocs: u64,
    frees: u64,
    grows: u64,
}

impl Inner {
    fn capacity(&self) -> usize {
        self.owner.len()
    }

    fn used(&self) -> usize {
        self.capacity() - self.free.len()
    }
}

/// Cloneable handle to the shared arena.
#[derive(Debug, Clone)]
pub struct BlockManager(Arc<Mutex<Inner>>);

impl BlockManager {
    pub fn new(capacity_blocks: usize) -> Self {
        BlockManager(Arc::new(Mutex::new(Inner {
            free: (0..capacity_blocks).rev().collect(),
            owner: vec![NO_OWNER; capacity_blocks],
            owned: Vec::new(),
            registered: Vec::new(),
            free_ids: Vec::new(),
            peak_used: 0,
            allocs: 0,
            frees: 0,
            grows: 0,
        })))
    }

    /// Lock helper. Ignores poisoning: the arena's invariants are restored
    /// before any panic below, and `SeqCache::drop` must still be able to
    /// return blocks while unwinding from an unrelated panic.
    fn inner(&self) -> MutexGuard<'_, Inner> {
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register a new sequence and return its arena identity.
    pub fn register(&self) -> SeqId {
        let mut g = self.inner();
        let id = match g.free_ids.pop() {
            Some(id) => id,
            None => {
                let id = g.owned.len() as u32;
                g.owned.push(0);
                g.registered.push(false);
                id
            }
        };
        g.owned[id as usize] = 0;
        g.registered[id as usize] = true;
        SeqId(id)
    }

    /// Drop a sequence: its id is recycled, and any block it still holds
    /// returns to the free list. Callers that know their slots (e.g.
    /// `SeqCache::drop`) release them first so the O(capacity) ownership
    /// scan below only runs as a leak-proofing fallback.
    pub fn unregister(&self, seq: SeqId) {
        let mut g = self.inner();
        let id = seq.0 as usize;
        if id >= g.registered.len() || !g.registered[id] {
            return; // already gone — unregister is idempotent for Drop
        }
        if g.owned[id] > 0 {
            for phys in 0..g.owner.len() {
                if g.owner[phys] == seq.0 {
                    g.owner[phys] = NO_OWNER;
                    g.free.push(phys);
                    g.frees += 1;
                }
            }
            g.owned[id] = 0;
        }
        g.registered[id] = false;
        g.free_ids.push(seq.0);
    }

    /// Allocate one block for `seq`. `None` when the arena is dry (the
    /// scheduler's preemption trigger).
    pub fn alloc(&self, seq: SeqId) -> Option<usize> {
        let mut g = self.inner();
        debug_assert!(g.registered[seq.0 as usize], "alloc on unregistered seq");
        let phys = g.free.pop()?;
        g.owner[phys] = seq.0;
        g.owned[seq.0 as usize] += 1;
        g.allocs += 1;
        let used = g.used();
        g.peak_used = g.peak_used.max(used);
        Some(phys)
    }

    /// Return one block. Panics on double free (slot already free) and on
    /// foreign free (slot held by another sequence) — both are memory-
    /// safety bugs in the caller, checked in O(1) in every build.
    pub fn release(&self, seq: SeqId, phys: usize) {
        let mut g = self.inner();
        let violation = if phys >= g.owner.len() {
            Some(format!("release of out-of-range block {phys}"))
        } else if g.owner[phys] == NO_OWNER {
            Some(format!("double free of block {phys}"))
        } else if g.owner[phys] != seq.0 {
            Some(format!(
                "foreign free: seq {} releasing block {phys} owned by seq {}",
                seq.0, g.owner[phys]
            ))
        } else {
            None
        };
        match violation {
            None => {
                g.owner[phys] = NO_OWNER;
                g.owned[seq.0 as usize] -= 1;
                g.free.push(phys);
                g.frees += 1;
            }
            Some(msg) => {
                drop(g); // release the lock before unwinding
                panic!("{msg}");
            }
        }
    }

    /// Extend the arena to `new_capacity` slots (device memory growth).
    pub fn grow(&self, new_capacity: usize) {
        let mut g = self.inner();
        let old = g.capacity();
        assert!(new_capacity >= old, "arena cannot shrink");
        for p in (old..new_capacity).rev() {
            g.free.push(p);
        }
        g.owner.resize(new_capacity, NO_OWNER);
        g.grows += 1;
    }

    pub fn capacity(&self) -> usize {
        self.inner().capacity()
    }

    pub fn free_count(&self) -> usize {
        self.inner().free.len()
    }

    pub fn used(&self) -> usize {
        self.inner().used()
    }

    /// Blocks currently held by `seq`.
    pub fn owned_by(&self, seq: SeqId) -> usize {
        let g = self.inner();
        g.owned.get(seq.0 as usize).copied().unwrap_or(0)
    }

    pub fn stats(&self) -> ArenaStats {
        let g = self.inner();
        ArenaStats {
            capacity: g.capacity(),
            used: g.used(),
            peak_used: g.peak_used,
            allocs: g.allocs,
            frees: g.frees,
            grows: g.grows,
            sequences: g.registered.iter().filter(|&&r| r).count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let m = BlockManager::new(3);
        let s = m.register();
        assert_eq!(m.alloc(s), Some(0));
        assert_eq!(m.alloc(s), Some(1));
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), None);
        assert_eq!(m.used(), 3);
        m.release(s, 1);
        assert_eq!(m.alloc(s), Some(1), "LIFO reuse of the freed slot");
        assert_eq!(m.stats().peak_used, 3);
    }

    #[test]
    fn per_seq_ownership_is_tracked() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        let p0 = m.alloc(a).unwrap();
        let _p1 = m.alloc(b).unwrap();
        let _p2 = m.alloc(b).unwrap();
        assert_eq!(m.owned_by(a), 1);
        assert_eq!(m.owned_by(b), 2);
        assert_eq!(m.used(), 3);
        m.release(a, p0);
        assert_eq!(m.owned_by(a), 0);
        assert_eq!(m.free_count(), 2);
    }

    #[test]
    fn unregister_releases_everything() {
        let m = BlockManager::new(4);
        let a = m.register();
        let b = m.register();
        m.alloc(a).unwrap();
        m.alloc(a).unwrap();
        m.alloc(b).unwrap();
        m.unregister(a);
        assert_eq!(m.used(), 1, "a's blocks returned to the arena");
        assert_eq!(m.stats().sequences, 1);
        m.unregister(a); // idempotent
        assert_eq!(m.used(), 1);
    }

    #[test]
    fn grow_extends_capacity() {
        let m = BlockManager::new(2);
        let s = m.register();
        m.alloc(s).unwrap();
        m.alloc(s).unwrap();
        assert_eq!(m.alloc(s), None);
        m.grow(4);
        assert_eq!(m.capacity(), 4);
        assert_eq!(m.alloc(s), Some(2));
        assert_eq!(m.alloc(s), Some(3));
        assert_eq!(m.stats().grows, 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let m = BlockManager::new(2);
        let s = m.register();
        let p = m.alloc(s).unwrap();
        m.release(s, p);
        m.release(s, p);
    }

    #[test]
    #[should_panic(expected = "foreign free")]
    fn foreign_free_panics() {
        let m = BlockManager::new(2);
        let a = m.register();
        let b = m.register();
        let p = m.alloc(a).unwrap();
        m.release(b, p);
    }

    #[test]
    fn id_recycling() {
        let m = BlockManager::new(2);
        let a = m.register();
        let raw = a.raw();
        m.unregister(a);
        let b = m.register();
        assert_eq!(b.raw(), raw, "freed id is recycled");
    }
}
