//! Paged KV-cache substrate (the vLLM-style memory manager the paper
//! builds on).
//!
//! Physical fixed-size *blocks* (pages) live in one process-wide shared
//! arena (`block_manager::BlockManager`); each sequence's cache allocates
//! from it and addresses its blocks through a *block table*:
//! `table[logical] = physical`. Pages are REFCOUNTED: identical full
//! prompt blocks are shared across sequences through a content-hash
//! prefix index (automatic prefix caching), freed only when the last
//! holder releases them, and copied-on-write before any in-place
//! mutation. All
//! eviction mechanisms — the paper's PagedEviction and every baseline —
//! operate purely on this host-side metadata; the device-side K/V buffers
//! are never moved or compacted. The decode graph receives the table plus a
//! per-slot validity mask, so:
//!
//!   * structured (block-wise) eviction = remove one table entry + free the
//!     physical slot — O(1) metadata, zero data movement;
//!   * unstructured (token-wise) eviction = clear one bit in the validity
//!     mask — the block stays allocated until every token in it is dead
//!     (the fragmentation the paper's Figures 5/6 illustrate).

pub mod block;
pub mod block_manager;
pub mod seq_cache;
pub mod stats;

pub use block::Block;
pub use block_manager::{ArenaStats, BlockManager, SeqId};
pub use seq_cache::{
    prefix_block_hashes, prefix_block_hashes_with_layout, BlockAlloc, ChannelLayout, KvSnapshot,
    SeqCache, SCORE_CHANNELS, SCORE_LAYOUT_V1,
};
pub use stats::CacheStats;
