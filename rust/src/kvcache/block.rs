//! Per-block (page) metadata. Physical slot allocation lives in the
//! shared arena (`block_manager::BlockManager`).

/// Maximum page size supported by the `u64` live-token bitmaps.
pub const MAX_BLOCK_SIZE: usize = 64;

/// One logical block (page) of a sequence's KV cache.
///
/// `phys` is the slot index into the sequence's device buffer; `fill` is how
/// many token positions have ever been written (only the newest block can
/// have `fill < block_size`); `live` is the bitmap of tokens that are still
/// visible to attention (unstructured eviction clears bits).
#[derive(Debug, Clone)]
pub struct Block {
    pub phys: usize,
    /// Global page id in the shared `BlockManager` arena backing this
    /// block (`phys` stays the slot inside the sequence's own device
    /// bucket — the value the block table serializes). In a standalone
    /// cache the two coincide.
    pub arena_slot: usize,
    /// True when `arena_slot` may be visible through the arena's prefix
    /// index — the block was published by this sequence or mapped from a
    /// hit, so other sequences can hold (or later acquire) references to
    /// the same physical page. In-place mutations must consult the arena
    /// first (`SeqCache::make_private`: copy-on-write while refcount > 1,
    /// unpublish otherwise). Blocks that never touched the index keep the
    /// flag false and skip the arena entirely on the hot mutation path.
    pub prefix_tracked: bool,
    pub fill: usize,
    live: u64,
    /// Per-token importance channels (aggregated over layers by the score
    /// tracker): `scores[c][off]`. Kept per-block so block-level aggregates
    /// are O(B) and token-level policies can do global scans.
    pub scores: [Vec<f32>; 3],
    /// Original sequence positions of the tokens (RoPE identity survives
    /// eviction; useful for traces and the StreamingLLM sink rule).
    pub positions: Vec<u32>,
}

impl Block {
    pub fn new(phys: usize, block_size: usize) -> Self {
        assert!(block_size <= MAX_BLOCK_SIZE, "page size > 64 unsupported");
        Block {
            phys,
            arena_slot: phys,
            prefix_tracked: false,
            fill: 0,
            live: 0,
            scores: [
                Vec::with_capacity(block_size),
                Vec::with_capacity(block_size),
                Vec::with_capacity(block_size),
            ],
            positions: Vec::with_capacity(block_size),
        }
    }

    /// Append a token (offset = current fill). Returns the offset.
    pub fn push(&mut self, position: u32, scores: [f32; 3]) -> usize {
        let off = self.fill;
        debug_assert!(off < MAX_BLOCK_SIZE);
        self.live |= 1 << off;
        for (c, s) in scores.iter().enumerate() {
            self.scores[c].push(*s);
        }
        self.positions.push(position);
        self.fill += 1;
        off
    }

    pub fn is_live(&self, off: usize) -> bool {
        off < self.fill && (self.live >> off) & 1 == 1
    }

    /// Kill one token (unstructured eviction). Returns false if it was
    /// already dead.
    pub fn kill(&mut self, off: usize) -> bool {
        if !self.is_live(off) {
            return false;
        }
        self.live &= !(1 << off);
        true
    }

    pub fn live_count(&self) -> usize {
        self.live.count_ones() as usize
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// True when some written tokens are dead — a fragmented page.
    pub fn is_partial(&self) -> bool {
        self.live_count() < self.fill
    }

    /// Mean of a score channel over LIVE tokens (paper Alg. 1 block score).
    pub fn mean_score(&self, channel: usize) -> f32 {
        let mut sum = 0.0f32;
        let mut n = 0usize;
        for off in 0..self.fill {
            if self.is_live(off) {
                sum += self.scores[channel][off];
                n += 1;
            }
        }
        if n == 0 {
            f32::INFINITY
        } else {
            sum / n as f32
        }
    }

    /// Raw liveness bitmap (bit `off` set = token at `off` is live).
    pub fn live_bits(&self) -> u64 {
        self.live
    }

    /// Write this block's validity-mask slots into `out` (length must be
    /// the block size): 1.0 for live offsets, 0.0 otherwise. Used by the
    /// from-scratch mask rebuild the incremental buffers are checked
    /// against.
    pub fn write_mask_into(&self, out: &mut [f32]) {
        for (off, slot) in out.iter_mut().enumerate() {
            *slot = if self.is_live(off) { 1.0 } else { 0.0 };
        }
    }

    /// Iterator over live (offset, position, [3]scores).
    pub fn live_tokens(&self) -> impl Iterator<Item = (usize, u32, [f32; 3])> + '_ {
        (0..self.fill).filter(|&o| self.is_live(o)).map(move |o| {
            (o, self.positions[o], [self.scores[0][o], self.scores[1][o], self.scores[2][o]])
        })
    }
}

// NOTE: the former per-sequence `BlockPool` free-list allocator lived here;
// it is superseded by the process-wide shared arena in `block_manager.rs`
// (every sequence now allocates through a `BlockManager` handle).

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_push_and_live() {
        let mut b = Block::new(3, 8);
        assert_eq!(b.push(100, [1.0, 2.0, 3.0]), 0);
        assert_eq!(b.push(101, [2.0, 3.0, 4.0]), 1);
        assert_eq!(b.live_count(), 2);
        assert!(b.is_live(0) && b.is_live(1) && !b.is_live(2));
        assert!(!b.is_partial());
    }

    #[test]
    fn block_kill_and_partial() {
        let mut b = Block::new(0, 4);
        for i in 0..4 {
            b.push(i, [1.0, 1.0, 1.0]);
        }
        assert!(b.kill(2));
        assert!(!b.kill(2), "double kill must be rejected");
        assert!(b.is_partial());
        assert_eq!(b.live_count(), 3);
        for o in [0, 1, 3] {
            assert!(b.kill(o));
        }
        assert!(b.is_empty());
    }

    #[test]
    fn write_mask_into_mirrors_liveness() {
        let mut b = Block::new(0, 4);
        b.push(0, [0.0; 3]);
        b.push(1, [0.0; 3]);
        b.push(2, [0.0; 3]);
        b.kill(1);
        let mut m = [9.0f32; 4];
        b.write_mask_into(&mut m);
        assert_eq!(m, [1.0, 0.0, 1.0, 0.0]);
        assert_eq!(b.live_bits(), 0b101);
    }

    #[test]
    fn block_mean_score_skips_dead() {
        let mut b = Block::new(0, 4);
        b.push(0, [1.0, 0.0, 0.0]);
        b.push(1, [3.0, 0.0, 0.0]);
        b.push(2, [100.0, 0.0, 0.0]);
        b.kill(2);
        assert_eq!(b.mean_score(0), 2.0);
    }

    #[test]
    fn empty_block_scores_infinite() {
        // An empty block must never win the "lowest score" eviction scan.
        let mut b = Block::new(0, 2);
        b.push(0, [1.0, 1.0, 1.0]);
        b.kill(0);
        assert_eq!(b.mean_score(0), f32::INFINITY);
    }

}
