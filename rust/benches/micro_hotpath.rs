//! Hot-path microbenchmarks — the profiling substrate for the perf log.
//! Times each layer of the decode path in isolation:
//!
//!   * L3 overheads: block-table/mask serialization (both the legacy
//!     from-scratch rebuild and the incremental borrow path, so a single
//!     run records the before/after), policy decisions, a full decode-step
//!     metadata cycle, JSON protocol parse, argmax;
//!   * with `--features xla`: PJRT decode-step / prefill execute per model
//!     and context bucket (L2+L1).
//!
//! Alongside the table it writes a machine-readable `BENCH_hotpath.json`
//! (op -> µs/op) so future PRs have a perf trajectory to compare against:
//!
//!     cargo bench --bench micro_hotpath
//!     cargo bench --bench micro_hotpath -- --iters 50 --json BENCH_hotpath.json

mod common;

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Instant;

use common::{bench_args, section};
use paged_eviction::api::RequestBuilder;
use paged_eviction::eviction::{make_policy, AttnFeedback, Decision};
use paged_eviction::kvcache::{prefix_block_hashes, BlockManager, SeqCache};
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::{FaultyBackend, SimBackend};
use paged_eviction::scheduler::{MultiEngine, Request, SchedConfig, Scheduler, SwapPool};
use paged_eviction::server::protocol::WireRequest;
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::json::Json;
use paged_eviction::util::stats::Table;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let args = bench_args(
        ArgSpec::new("micro_hotpath", "per-layer hot path microbenches")
            .opt("iters", "20", "iterations per measurement")
            .opt("models", "sim-1b,sim-3b,sim-8b", "models (PJRT sections)")
            .opt("json", "BENCH_hotpath.json", "machine-readable output path (\"\" = skip)"),
    );
    let iters = args.get_usize("iters");

    #[cfg(feature = "xla")]
    pjrt_sections(&args, iters);
    #[cfg(not(feature = "xla"))]
    println!("(PJRT decode/prefill sections skipped: built without --features xla)");

    // ---- L3 overheads ----
    section("L3 coordinator overheads (µs/op)");
    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut t = Table::new(&["operation", "µs/op"]);
    let record = |t: &mut Table, rows: &mut Vec<(String, f64)>, name: &str, us: f64| {
        t.row(vec![name.into(), format!("{us:.3}")]);
        rows.push((name.to_string(), us));
    };

    let mut cache = SeqCache::new(16, 64);
    let pre: Vec<(u32, [f32; 3])> = (0..512u32).map(|i| (i, [0.5, 0.5, 0.5])).collect();
    cache.load_prefill(&pre, 512);

    // Both serialization variants end with the same consumer pass (a
    // checksum standing in for the literal/upload copy that reads the
    // buffer once), so the rows compare build-cost only and the
    // incremental numbers stay meaningful instead of timing a bare borrow
    // the optimizer can hoist.
    fn consume_i32(t: &[i32]) -> i64 {
        t.iter().map(|&x| x as i64).sum()
    }
    fn consume_f32(m: &[f32]) -> f64 {
        m.iter().map(|&x| x as f64).sum()
    }

    // serialization: legacy from-scratch rebuild (the pre-PR per-step cost)
    let us = time_it(iters * 100, || {
        std::hint::black_box(consume_i32(&cache.rebuild_block_table(64)));
    }) * 1e6;
    record(&mut t, &mut rows, "block_table rebuild+consume (64 blocks)", us);
    let us = time_it(iters * 100, || {
        std::hint::black_box(consume_f32(&cache.rebuild_valid_mask(64)));
    }) * 1e6;
    record(&mut t, &mut rows, "valid_mask rebuild+consume (1024 slots)", us);

    // serialization: incremental borrow path (the post-PR per-step cost)
    let us = time_it(iters * 100, || {
        std::hint::black_box(consume_i32(cache.block_table(64)));
    }) * 1e6;
    record(&mut t, &mut rows, "block_table incremental+consume (64 blocks)", us);
    let us = time_it(iters * 100, || {
        std::hint::black_box(consume_f32(cache.valid_mask(64)));
    }) * 1e6;
    record(&mut t, &mut rows, "valid_mask incremental+consume (1024 slots)", us);

    // policy scans over the same cache
    let paged = make_policy("paged").unwrap();
    let us = time_it(iters * 100, || {
        std::hint::black_box(paged.post_append(&cache, 256));
    }) * 1e6;
    record(&mut t, &mut rows, "paged post_append scan (32 blocks)", us);
    let ikn = make_policy("inverse_key_norm").unwrap();
    let us = time_it(iters * 10, || {
        std::hint::black_box(ikn.post_append(&cache, 256));
    }) * 1e6;
    record(&mut t, &mut rows, "inverse_key_norm global scan (512 tokens)", us);

    // attn_feedback_step: what a feedback-consuming policy adds per decode
    // step — assemble the O(live) attention-mass vector (the sim backend's
    // positional model) and take the guided decision instead of the proxy.
    let sa = make_policy("self_attn").unwrap();
    let horizon = cache.next_position();
    let us = time_it(iters * 10, || {
        let fb = AttnFeedback {
            mass: (0..horizon)
                .map(|p| paged_eviction::sim::positional_mass(p, horizon))
                .collect(),
        };
        std::hint::black_box(sa.post_append_feedback(&cache, 256, Some(&fb)));
    }) * 1e6;
    record(&mut t, &mut rows, "attn_feedback_step (512-pos mass + guided decision)", us);

    // autotune_pick: the per-request cost of one `--policy auto`
    // resolution — lock-free arena pressure snapshot, pure table choice,
    // counter record. This sits on the submit path, never in decode.
    let aarena = BlockManager::new(4096);
    let mut astats = paged_eviction::scheduler::AutotuneStats::default();
    let mut aplen = 0usize;
    let us = time_it(iters * 100, || {
        aplen = (aplen % 512) + 17;
        let snap = paged_eviction::scheduler::PressureSnapshot::read(&aarena);
        let c = paged_eviction::scheduler::autotune::choose(aplen, 0, 1024, 16, &snap);
        astats.record(c.policy);
        std::hint::black_box(c);
    }) * 1e6;
    assert!(astats.total() > 0, "the autotuner always resolves to something");
    record(&mut t, &mut rows, "autotune_pick (snapshot + choose + record)", us);

    // full decode-step metadata cycle: alloc-if-needed + append + policy +
    // evict + incremental serialization (what the runtime pays per token,
    // minus the PJRT execute itself)
    let mut dc = SeqCache::new(16, 64);
    let pre: Vec<(u32, [f32; 3])> = (0..256u32).map(|i| (i, [0.5, 0.5, 0.5])).collect();
    dc.load_prefill(&pre, 256);
    let dpaged = make_policy("paged").unwrap();
    let mut step = 0u32;
    let us = time_it(iters * 100, || {
        assert!(dc.ensure_block());
        dc.append([0.4 + (step % 5) as f32 * 1e-3; 3]);
        step += 1;
        if let Decision::EvictBlock(i) = dpaged.post_append(&dc, 256) {
            dc.evict_block(i);
        }
        let nb = dc.capacity_blocks();
        std::hint::black_box((dc.block_table(nb).len(), dc.valid_mask(nb).len()));
    }) * 1e6;
    record(&mut t, &mut rows, "decode-step metadata cycle (paged, incremental)", us);

    let line = r#"{"id": 7, "prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 16, "budget": 128, "policy": "paged"}"#;
    let us = time_it(iters * 100, || {
        std::hint::black_box(WireRequest::parse(line).unwrap());
    }) * 1e6;
    record(&mut t, &mut rows, "JSON request parse", us);

    let logits: Vec<f32> = (0..4096).map(|i| ((i * 2654435761u64 as usize) % 997) as f32).collect();
    let us = time_it(iters * 100, || {
        std::hint::black_box(argmax(&logits));
    }) * 1e6;
    record(&mut t, &mut rows, "argmax (4096 logits)", us);

    // prefix cache: the per-prefill cost of hashing a prompt's block chain
    // and probing the arena index (read-only, what admission pays) ...
    let arena = BlockManager::new(256);
    let entries: Vec<(u32, [f32; 3])> = (0..64u32).map(|i| (i, [0.25; 3])).collect();
    let keys: Vec<u64> = (0..64u64).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15)).collect();
    let mut publisher = SeqCache::new_shared(16, 8, &arena);
    publisher
        .try_load_prefill_cached(&entries, &keys, 64)
        .expect("publisher prefill fits");
    let us = time_it(iters * 100, || {
        let hashes = prefix_block_hashes(16, &entries, &keys);
        std::hint::black_box(arena.count_leading_hits(&hashes));
    }) * 1e6;
    record(&mut t, &mut rows, "prefix_lookup chain+probe (4 blocks of 16)", us);

    // ... and the copy-on-write cycle: map 4 published blocks by refcount,
    // unshare one ahead of an in-place write, drop (release by refcount)
    let us = time_it(iters * 10, || {
        let mut borrower = SeqCache::new_shared(16, 8, &arena);
        let hits = borrower
            .try_load_prefill_cached(&entries, &keys, 64)
            .expect("borrower prefill fits");
        assert_eq!(hits, 4, "publisher's chain must hit");
        borrower.make_private(0).expect("arena has CoW headroom");
    }) * 1e6;
    record(&mut t, &mut rows, "cow_copy cycle (hit 4 blocks + make_private)", us);

    // cancel_request: the session API's synchronous teardown — admit one
    // request (prefill), run one decode round, cancel it mid-decode. The
    // assertion inside is the contract: every arena block is back the
    // moment cancel returns.
    let mut csched = Scheduler::new_sim(SchedConfig {
        page_size: 16,
        max_concurrency: 4,
        max_live_blocks: 4096,
        ..Default::default()
    });
    let cprompt: Vec<u32> = (0..32u32).collect();
    let mut next_id = 0u64;
    let us = time_it(iters * 10, || {
        next_id += 1;
        let mut req = Request::new(next_id, cprompt.clone(), 8);
        req.budget = 64;
        csched.submit(req);
        csched.step().expect("schedule step");
        assert!(csched.cancel(next_id), "request must be cancellable mid-decode");
        assert_eq!(csched.live_blocks(), 0, "cancel returned every block");
        let _ = csched.take_events();
    }) * 1e6;
    record(&mut t, &mut rows, "cancel_request (submit+prefill+cancel)", us);

    // fault_passthrough: the FaultyBackend wrapper in passthrough mode
    // (no plan) sits on the decode hot path whenever fault injection is
    // wired in — this row pins its per-step overhead at ~zero against
    // the gate ceiling.
    let mut fsched = Scheduler::with_backend(
        FaultyBackend::passthrough(SimBackend::new(16)),
        SchedConfig {
            page_size: 16,
            max_concurrency: 4,
            max_live_blocks: 4096,
            ..Default::default()
        },
    );
    let fprompt: Vec<u32> = (0..32u32).collect();
    // one request that outlives the timed window, so every timed step is
    // a steady-state single-sequence decode round through the wrapper
    let mut freq = Request::new(1, fprompt, iters * 10 + 16);
    freq.budget = 64;
    fsched.submit(freq);
    fsched.step().expect("admission round");
    let us = time_it(iters * 10, || {
        fsched.step().expect("decode round");
        let _ = fsched.take_events();
    }) * 1e6;
    record(&mut t, &mut rows, "fault_passthrough decode step (no plan)", us);

    // worker_handoff: the multi-worker engine's donation primitive —
    // steal a queue-tail entry from a loaded worker, accept it on an idle
    // peer (Scheduler::donate_to = steal_tail + inject). No block traffic
    // moves: arena, swap pool and memos are shared engine-wide, so the
    // handoff must stay queue-surgery cheap.
    let harena = BlockManager::new(4096);
    harena.set_watermarks(0.7, 0.85);
    let hswap = Arc::new(SwapPool::new(1 << 24));
    let hserial = Arc::new(AtomicU64::new(0));
    let hcfg = SchedConfig {
        page_size: 16,
        max_concurrency: 4,
        max_live_blocks: 4096,
        ..Default::default()
    };
    let mut wa = Scheduler::with_shared(
        SimBackend::new(16),
        hcfg.clone(),
        harena.clone(),
        hswap.clone(),
        hserial.clone(),
    );
    let mut wb = Scheduler::with_shared(SimBackend::new(16), hcfg, harena, hswap, hserial);
    for id in 1..=8u64 {
        let mut r = Request::new(id, (0..32u32).collect(), 8);
        r.budget = 64;
        wa.submit(r);
    }
    let us = time_it(iters * 100, || {
        assert!(wa.donate_to(&mut wb), "worker A always has a queued entry");
        assert!(wb.donate_to(&mut wa), "worker B hands it straight back");
    }) * 1e6
        / 2.0;
    record(&mut t, &mut rows, "worker_handoff (steal_tail + inject)", us);

    // cross_worker_preempt: what the owner of the GLOBAL victim pays when
    // a gated peer posts reclaim pressure — read the local victim key,
    // preempt the victim into the shared swap pool, then readmit it
    // (swap restore + decode round) once the pressure clears. One full
    // preempt/restore cycle per iteration.
    let mut psched = Scheduler::new_sim(SchedConfig {
        page_size: 16,
        max_concurrency: 4,
        max_live_blocks: 4096,
        swap_bytes: 1 << 26,
        ..Default::default()
    });
    let mut preq = Request::new(1, (0..64u32).collect(), iters * 10 + 16);
    preq.budget = 128;
    psched.submit(preq);
    psched.step().expect("admission round");
    let us = time_it(iters * 10, || {
        std::hint::black_box(psched.min_victim_key());
        assert!(psched.preempt_min(), "one sequence is always running");
        psched.step().expect("restore round");
        let _ = psched.take_events();
    }) * 1e6;
    record(&mut t, &mut rows, "cross_worker_preempt (preempt_min + restore round)", us);

    // alloc_batch_16 / release_batch_16: the batched arena primitives —
    // one global lock acquisition moves 16 blocks either direction
    // (versus 16 acquisitions for the per-block loop they replaced).
    // Timed as the two halves of an alloc_many/release_many cycle so
    // neither row hides the other's cost.
    let barena = BlockManager::new(64);
    let bseq = barena.register();
    let bn = iters * 100;
    let (mut alloc_s, mut release_s) = (0.0f64, 0.0f64);
    for _ in 0..bn {
        let t0 = Instant::now();
        let blocks = barena.alloc_many(bseq, 16).expect("64-block arena always fits 16");
        alloc_s += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        barena.release_many(bseq, &blocks);
        release_s += t0.elapsed().as_secs_f64();
    }
    record(&mut t, &mut rows, "alloc_batch_16 (alloc_many, one lock)", alloc_s / bn as f64 * 1e6);
    record(
        &mut t,
        &mut rows,
        "release_batch_16 (release_many, one lock)",
        release_s / bn as f64 * 1e6,
    );

    // arena_contended_alloc: 4 threads hammering one shared arena through
    // per-worker slot caches — the decontention number. Steady state each
    // worker recycles its own leased stock, so the global lock is cold;
    // µs is per alloc/release pair per thread (wall / (4 × rounds)).
    let carena = BlockManager::new(256);
    let crounds = iters * 100;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let w = carena.with_worker_cache();
            scope.spawn(move || {
                let seq = w.register();
                for _ in 0..crounds {
                    let b = w.alloc(seq).expect("256 blocks cover 4 cached workers");
                    w.release(seq, b);
                }
                w.unregister(seq);
            });
        }
    });
    let us = t0.elapsed().as_secs_f64() / (4 * crounds) as f64 * 1e6;
    record(&mut t, &mut rows, "arena_contended_alloc (4 threads, cached)", us);

    // engine aggregate decode throughput: the same 2048-token workload
    // (16 requests x 128 tokens, arena sized so nothing contends — pure
    // decode scaling) through the multi-worker engine at 1 and 4 workers.
    // The gate holds 4 workers to >= 2.5x the 1-worker number on machines
    // with >= 4 cores; the core count rides along in the JSON so
    // constrained runners skip the ratio check, not the ceilings.
    let engine_tput = |workers: usize| -> f64 {
        let mut engine = MultiEngine::new_sim(SchedConfig {
            page_size: 16,
            max_concurrency: 4,
            max_live_blocks: 4096,
            workers,
            ..Default::default()
        });
        let t0 = Instant::now();
        for i in 0..16u32 {
            let prompt: Vec<u32> = (0..64u32).map(|k| (k * 7 + i) % 200).collect();
            engine
                .submit_builder(
                    RequestBuilder::new(prompt)
                        .max_new_tokens(128)
                        .policy("paged")
                        .budget(9999),
                )
                .expect("submit");
        }
        let outs = engine.run_to_completion();
        let secs = t0.elapsed().as_secs_f64();
        let toks: usize = outs.iter().map(|o| o.tokens.len()).sum();
        assert_eq!(toks, 16 * 128, "every request decodes to its cap");
        let _ = engine.shutdown(std::time::Duration::from_secs(5));
        secs / toks as f64
    };
    let us1 = engine_tput(1) * 1e6;
    record(&mut t, &mut rows, "engine decode throughput, 1 worker (us/token)", us1);
    let us4 = engine_tput(4) * 1e6;
    record(&mut t, &mut rows, "engine decode throughput, 4 workers (us/token)", us4);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    record(&mut t, &mut rows, "cpu cores available", cores as f64);

    print!("{}", t.render());

    // speedup summary + machine-readable dump
    let lookup = |name: &str| rows.iter().find(|(n, _)| n == name).map(|&(_, v)| v);
    if let (Some(rb_t), Some(inc_t), Some(rb_m), Some(inc_m)) = (
        lookup("block_table rebuild+consume (64 blocks)"),
        lookup("block_table incremental+consume (64 blocks)"),
        lookup("valid_mask rebuild+consume (1024 slots)"),
        lookup("valid_mask incremental+consume (1024 slots)"),
    ) {
        println!(
            "\nserialization speedup (rebuild -> incremental): table {:.1}x, mask {:.1}x",
            rb_t / inc_t.max(1e-9),
            rb_m / inc_m.max(1e-9),
        );
    }
    println!(
        "engine scaling (1 -> 4 workers): {:.2}x aggregate decode throughput on {cores} core(s)",
        us1 / us4.max(1e-9),
    );

    let json_path = args.get("json");
    if !json_path.is_empty() {
        let obj = Json::obj(
            rows.iter()
                .map(|(k, v)| (k.as_str(), Json::num(*v)))
                .collect(),
        );
        std::fs::write(json_path, obj.to_string()).expect("writing bench json");
        println!("wrote {json_path} (op -> µs/op)");
    }
}

#[cfg(feature = "xla")]
fn pjrt_sections(args: &paged_eviction::util::args::Args, iters: usize) {
    use common::artifacts_dir;
    use paged_eviction::runtime::{Engine, ModelRunner};
    use paged_eviction::util::rng::Pcg32;
    use paged_eviction::workload::recall;

    let engine = Engine::new(artifacts_dir()).expect("make artifacts first");

    // ---- decode step per model x context bucket ----
    section("decode step latency (ms) per context bucket [PJRT execute, page 16]");
    let buckets = [128usize, 256, 512, 1024];
    let mut header = vec!["model".to_string()];
    header.extend(buckets.iter().map(|b| format!("ctx={b}")));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for model in args.get_list("models") {
        let runner = ModelRunner::new(&engine, &model, 16).unwrap();
        let mut row = vec![model.clone()];
        for &bucket in &buckets {
            // build a sequence whose cache sits in this bucket
            let mut rng = Pcg32::new(1);
            let plen = (bucket - 32).min(500).max(16);
            let p = recall::make_prompt(&mut rng, plen / 2 * 2, 0.5);
            let (mut seq, logits) = runner
                .prefill(&p.tokens, bucket - 2 * 16, make_policy("paged").unwrap())
                .unwrap();
            let mut tok = argmax(&logits);
            // warm the graph
            let o = runner.decode_step(&mut seq, tok).unwrap();
            tok = argmax(&o.logits);
            let ms = time_it(iters, || {
                let o = runner.decode_step(&mut seq, tok).unwrap();
                tok = argmax(&o.logits);
            }) * 1e3;
            row.push(format!("{ms:.2}"));
        }
        t.row(row);
    }
    print!("{}", t.render());

    // ---- prefill per bucket ----
    section("prefill latency (ms) per prompt bucket");
    let pbuckets = [64usize, 128, 256, 512];
    let mut header = vec!["model".to_string()];
    header.extend(pbuckets.iter().map(|b| format!("P={b}")));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for model in args.get_list("models") {
        let runner = ModelRunner::new(&engine, &model, 16).unwrap();
        let mut row = vec![model.clone()];
        for &pb in &pbuckets {
            let mut rng = Pcg32::new(2);
            let p = recall::make_prompt(&mut rng, pb - 2, 0.5);
            // warm
            let _ = runner.prefill(&p.tokens, 1024, make_policy("full").unwrap());
            let ms = time_it(iters.min(10), || {
                let _ = runner
                    .prefill(&p.tokens, 1024, make_policy("full").unwrap())
                    .unwrap();
            }) * 1e3;
            row.push(format!("{ms:.2}"));
        }
        t.row(row);
    }
    print!("{}", t.render());
}
