//! Hot-path microbenchmarks — the profiling substrate for EXPERIMENTS.md
//! §Perf. Times each layer of the decode path in isolation:
//!
//!   * PJRT decode-step execute per model and context bucket (L2+L1)
//!   * prefill execute per prompt bucket
//!   * L3 overheads: block-table/mask serialization, literal construction,
//!     policy decisions, JSON protocol parse/serialize
//!
//!     cargo bench --bench micro_hotpath
//!     cargo bench --bench micro_hotpath -- --iters 50

mod common;

use std::time::Instant;

use common::{artifacts_dir, bench_args, section};
use paged_eviction::eviction::make_policy;
use paged_eviction::kvcache::SeqCache;
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::{Engine, ModelRunner};
use paged_eviction::server::protocol::WireRequest;
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::util::stats::Table;
use paged_eviction::workload::recall;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let args = bench_args(
        ArgSpec::new("micro_hotpath", "per-layer hot path microbenches")
            .opt("iters", "20", "iterations per measurement")
            .opt("models", "sim-1b,sim-3b,sim-8b", "models"),
    );
    let iters = args.get_usize("iters");
    let engine = Engine::new(artifacts_dir()).expect("make artifacts first");

    // ---- decode step per model x context bucket ----
    section("decode step latency (ms) per context bucket [PJRT execute, page 16]");
    let buckets = [128usize, 256, 512, 1024];
    let mut header = vec!["model".to_string()];
    header.extend(buckets.iter().map(|b| format!("ctx={b}")));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for model in args.get_list("models") {
        let runner = ModelRunner::new(&engine, &model, 16).unwrap();
        let mut row = vec![model.clone()];
        for &bucket in &buckets {
            // build a sequence whose cache sits in this bucket
            let mut rng = Pcg32::new(1);
            let plen = (bucket - 32).min(500).max(16);
            let p = recall::make_prompt(&mut rng, plen / 2 * 2, 0.5);
            let (mut seq, logits) = runner
                .prefill(&p.tokens, bucket - 2 * 16, make_policy("paged").unwrap())
                .unwrap();
            let mut tok = argmax(&logits);
            // warm the graph
            let o = runner.decode_step(&mut seq, tok).unwrap();
            tok = argmax(&o.logits);
            let ms = time_it(iters, || {
                let o = runner.decode_step(&mut seq, tok).unwrap();
                tok = argmax(&o.logits);
            }) * 1e3;
            row.push(format!("{ms:.2}"));
        }
        t.row(row);
    }
    print!("{}", t.render());

    // ---- prefill per bucket ----
    section("prefill latency (ms) per prompt bucket");
    let pbuckets = [64usize, 128, 256, 512];
    let mut header = vec!["model".to_string()];
    header.extend(pbuckets.iter().map(|b| format!("P={b}")));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for model in args.get_list("models") {
        let runner = ModelRunner::new(&engine, &model, 16).unwrap();
        let mut row = vec![model.clone()];
        for &pb in &pbuckets {
            let mut rng = Pcg32::new(2);
            let p = recall::make_prompt(&mut rng, pb - 2, 0.5);
            // warm
            let _ = runner.prefill(&p.tokens, 1024, make_policy("full").unwrap());
            let ms = time_it(iters.min(10), || {
                let _ = runner
                    .prefill(&p.tokens, 1024, make_policy("full").unwrap())
                    .unwrap();
            }) * 1e3;
            row.push(format!("{ms:.2}"));
        }
        t.row(row);
    }
    print!("{}", t.render());

    // ---- L3 overheads ----
    section("L3 coordinator overheads (µs)");
    let mut t = Table::new(&["operation", "µs/op"]);
    let mut cache = SeqCache::new(16, 64);
    let pre: Vec<(u32, [f32; 3])> = (0..512u32).map(|i| (i, [0.5, 0.5, 0.5])).collect();
    cache.load_prefill(&pre, 512);
    let us = time_it(iters * 100, || {
        std::hint::black_box(cache.block_table_i32(64));
    }) * 1e6;
    t.row(vec!["block_table_i32 (64 blocks)".into(), format!("{us:.2}")]);
    let us = time_it(iters * 100, || {
        std::hint::black_box(cache.valid_mask_f32(64));
    }) * 1e6;
    t.row(vec!["valid_mask_f32 (1024 slots)".into(), format!("{us:.2}")]);

    let paged = make_policy("paged").unwrap();
    let us = time_it(iters * 100, || {
        std::hint::black_box(paged.post_append(&cache, 256));
    }) * 1e6;
    t.row(vec!["paged post_append scan (32 blocks)".into(), format!("{us:.2}")]);
    let ikn = make_policy("inverse_key_norm").unwrap();
    let us = time_it(iters * 10, || {
        std::hint::black_box(ikn.post_append(&cache, 256));
    }) * 1e6;
    t.row(vec!["inverse_key_norm global scan (512 tokens)".into(), format!("{us:.2}")]);

    let line = r#"{"id": 7, "prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 16, "budget": 128, "policy": "paged"}"#;
    let us = time_it(iters * 100, || {
        std::hint::black_box(WireRequest::parse(line).unwrap());
    }) * 1e6;
    t.row(vec!["JSON request parse".into(), format!("{us:.2}")]);
    print!("{}", t.render());
    println!("\n(use these rows for the EXPERIMENTS.md §Perf before/after log)");
}
