//! Figure 4 — page-size ablation: throughput (a-c) and accuracy (d-i)
//! across page sizes {8, 16, 32} for each KV compression method.
//!
//!     cargo bench --bench fig4_page_size
//!     cargo bench --bench fig4_page_size -- --models sim-1b --pages 8,16,32
//!
//! Accuracy has two tracks, as in Fig 2: the simulator at paper scale
//! (GovReport/MultiNews ROUGE analogue; policy x page cells fan out with
//! `std::thread::scope`, numerically identical to a serial run) and — with
//! `--features xla` — the real model's full-cache fidelity (ROUGE-L over
//! token ids of the evicted-cache generation vs the full-cache generation,
//! the measurable analogue of "less than 3-5% degradation from Full
//! Cache") plus the throughput sweep.

mod common;

use common::{bench_args, section};
use paged_eviction::eviction::make_policy;
use paged_eviction::sim::attention_sim::{simulate_episode, SimConfig};
use paged_eviction::sim::datasets::dataset;
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::stats::Table;

const POLICIES: [&str; 4] = ["full", "streaming", "inverse_key_norm", "paged"];

fn main() {
    let args = bench_args(
        ArgSpec::new("fig4_page_size", "page-size ablation (paper Fig. 4)")
            .opt("models", "sim-1b,sim-3b", "models for the throughput sweep")
            .opt("pages", "8,16,32", "page sizes")
            .opt("budget", "128", "real-track budget tokens")
            .opt("sim-budget", "1024", "sim-track budget tokens")
            .opt("requests", "3", "requests per throughput cell")
            .opt("gen", "96", "output tokens per request")
            .opt("episodes", "12", "sim episodes per accuracy cell")
            .opt("fidelity-prompts", "6", "real fidelity prompts per cell"),
    );
    let pages = args.get_usize_list("pages");

    #[cfg(feature = "xla")]
    throughput_track(&args, &pages);
    #[cfg(not(feature = "xla"))]
    println!("(throughput a-c skipped: built without --features xla)");

    // ---- (d-i) accuracy vs page size: SIM track ----
    let sim_budget = args.get_usize("sim-budget");
    let episodes = args.get_usize("episodes");
    for ds in ["govreport", "multinews"] {
        let d = dataset(ds).unwrap();
        section(&format!(
            "Fig 4 d-i (SIM, {ds}): score vs page size, budget {sim_budget} \
             (full-cache {:.1})",
            d.full_score
        ));
        let mut cells = vec![vec![0.0f64; pages.len()]; POLICIES.len()];
        std::thread::scope(|s| {
            for (pi, row) in cells.iter_mut().enumerate() {
                for (gi, slot) in row.iter_mut().enumerate() {
                    let page = pages[gi];
                    s.spawn(move || {
                        let p = make_policy(POLICIES[pi]).unwrap();
                        let mut acc = 0.0;
                        for e in 0..episodes {
                            let cfg = SimConfig {
                                budget: sim_budget,
                                page_size: page,
                                seed: e as u64 * 101,
                                ..Default::default()
                            };
                            acc += simulate_episode(d, p.as_ref(), &cfg).score;
                        }
                        *slot = acc / episodes as f64;
                    });
                }
            }
        });
        let mut header = vec!["policy".to_string()];
        header.extend(pages.iter().map(|p| format!("page={p}")));
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for (pi, row) in cells.iter().enumerate() {
            let mut out = vec![POLICIES[pi].to_string()];
            out.extend(row.iter().map(|v| format!("{v:.1}")));
            t.row(out);
        }
        print!("{}", t.render());
    }

    #[cfg(feature = "xla")]
    fidelity_track(&args, &pages);
    #[cfg(not(feature = "xla"))]
    println!("\n(REAL fidelity track skipped: built without --features xla)");
}

#[cfg(feature = "xla")]
fn throughput_track(args: &paged_eviction::util::args::Args, pages: &[usize]) {
    use common::artifacts_dir;
    use paged_eviction::runtime::Engine;
    use paged_eviction::scheduler::{Request, SchedConfig, Scheduler};
    use paged_eviction::util::rng::Pcg32;
    use paged_eviction::workload::recall;

    let engine = Engine::new(artifacts_dir()).expect("make artifacts first");
    let models = args.get_list("models");
    let budget = args.get_usize("budget");
    for model in &models {
        section(&format!(
            "Fig 4 a-c ({model}): throughput (tok/s) vs page size, budget {budget}"
        ));
        let mut header = vec!["policy".to_string()];
        header.extend(pages.iter().map(|p| format!("page={p}")));
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for policy in POLICIES {
            let mut row = vec![policy.to_string()];
            for &page in pages {
                let mut sched = Scheduler::new(
                    &engine,
                    SchedConfig {
                        model: model.clone(),
                        page_size: page,
                        max_concurrency: 5,
                        max_live_blocks: 100_000,
                        ..SchedConfig::default()
                    },
                )
                .expect("scheduler");
                let mut rng = Pcg32::with_stream(4242, page as u64);
                for i in 0..args.get_usize("requests") {
                    let frac = 0.2 + 0.6 * rng.f64();
                    let p = recall::make_prompt(&mut rng, 128, frac);
                    let mut req = Request::new(i as u64 + 1, p.tokens, args.get_usize("gen"));
                    req.budget = budget;
                    req.policy = policy.to_string();
                    sched.submit(req);
                }
                sched.run_to_completion().expect("run");
                row.push(format!("{:.0}", sched.throughput_tok_s()));
            }
            t.row(row);
        }
        print!("{}", t.render());
    }
}

#[cfg(feature = "xla")]
fn fidelity_track(args: &paged_eviction::util::args::Args, pages: &[usize]) {
    use common::artifacts_dir;
    use paged_eviction::runtime::model_runner::argmax;
    use paged_eviction::runtime::{Engine, ModelRunner};
    use paged_eviction::sim::rouge::rouge_l_ids;
    use paged_eviction::util::rng::Pcg32;
    use paged_eviction::workload::recall;

    fn generate(
        runner: &ModelRunner,
        prompt: &[u32],
        budget: usize,
        policy: &str,
        len: usize,
    ) -> Vec<u32> {
        let (mut seq, logits) = runner
            .prefill(prompt, budget, make_policy(policy).unwrap())
            .unwrap();
        let mut tok = argmax(&logits);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(tok);
            let o = runner.decode_step(&mut seq, tok).unwrap();
            tok = argmax(&o.logits);
        }
        out
    }

    let engine = Engine::new(artifacts_dir()).expect("make artifacts first");
    let budget = args.get_usize("budget");
    section(&format!(
        "Fig 4 (REAL, sim-1b): full-cache fidelity (ROUGE-L of generation \
         vs full-cache generation), budget {budget}"
    ));
    let n = args.get_usize("fidelity-prompts");
    let gen_len = 48usize;
    let mut header = vec!["policy".to_string()];
    header.extend(pages.iter().map(|p| format!("page={p}")));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    // reference generations per (page, prompt) under full cache
    for policy in POLICIES {
        let mut row = vec![policy.to_string()];
        for &page in pages {
            let runner = ModelRunner::new(&engine, "sim-1b", page).unwrap();
            let mut acc = 0.0;
            for i in 0..n {
                let mut rng = Pcg32::with_stream(31337 + i as u64, page as u64);
                let frac = 0.2 + 0.6 * rng.f64();
                let p = recall::make_prompt(&mut rng, 192, frac);
                let reference = generate(&runner, &p.tokens, 100_000, "full", gen_len);
                let candidate = generate(&runner, &p.tokens, budget, policy, gen_len);
                acc += rouge_l_ids(&candidate, &reference);
            }
            row.push(format!("{:.2}", acc / n as f64));
        }
        t.row(row);
    }
    print!("{}", t.render());
    println!("(1.00 = byte-identical to full-cache output)");
}
