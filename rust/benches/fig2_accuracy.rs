//! Figure 2 — Accuracy vs cache budget, five LongBench datasets.
//!
//! Two tracks (DESIGN.md §4/§5):
//!   SIM:  paper-scale budgets (256..4096) on the attention-mass simulator,
//!         five dataset profiles, plus the H2O oracle upper bound.
//!   REAL: sim-1b through the full runtime — full-cache fidelity (ROUGE-L
//!         vs the full-cache generation) + needle recall when trained
//!         (budgets scaled to the model's context window).
//!
//!     cargo bench --bench fig2_accuracy
//!     cargo bench --bench fig2_accuracy -- --track sim --episodes 64

mod common;

use common::{artifacts_dir, bench_args, section};
use paged_eviction::eviction::{make_policy, ALL_POLICIES};
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::{Engine, ModelRunner};
use paged_eviction::sim::attention_sim::{simulate_episode, SimConfig};
use paged_eviction::sim::datasets::DATASETS;
use paged_eviction::sim::H2oOracle;
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::util::stats::Table;
use paged_eviction::workload::recall;

fn main() {
    let args = bench_args(
        ArgSpec::new("fig2_accuracy", "accuracy vs cache budget (paper Fig. 2)")
            .opt("track", "both", "sim | real | both")
            .opt("episodes", "16", "sim episodes per cell")
            .opt("prompts", "16", "real prompts per cell")
            .flag("oracle", "include the H2O oracle row (sim track)"),
    );
    let track = args.get("track");
    if track == "sim" || track == "both" {
        sim_track(args.get_usize("episodes"), true);
    }
    if track == "real" || track == "both" {
        real_track(args.get_usize("prompts"));
    }
}

fn sim_track(episodes: usize, oracle: bool) {
    section("Fig 2 (SIM track): score vs budget, page 16");
    let budgets = [256usize, 512, 1024, 2048, 4096];
    for d in &DATASETS {
        let mut header = vec!["policy".to_string()];
        header.extend(budgets.iter().map(|b| format!("b={b}")));
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for pol in ALL_POLICIES {
            let p = make_policy(pol).unwrap();
            let mut row = vec![pol.to_string()];
            for &budget in &budgets {
                let mut acc = 0.0;
                for e in 0..episodes {
                    let cfg = SimConfig {
                        budget,
                        seed: e as u64 * 7919,
                        ..Default::default()
                    };
                    acc += simulate_episode(d, p.as_ref(), &cfg).score;
                }
                row.push(format!("{:.1}", acc / episodes as f64));
            }
            t.row(row);
        }
        if oracle {
            // H2O oracle needs the true importances — rebuild per episode
            // with a policy constructed from the episode's own profile. We
            // approximate by giving the oracle the channel-0 noiseless
            // signal: rerun with zero proxy noise on channel 0.
            let mut row = vec!["h2o_oracle*".to_string()];
            for &budget in &budgets {
                let mut acc = 0.0;
                for e in 0..episodes {
                    let cfg = SimConfig {
                        budget,
                        seed: e as u64 * 7919,
                        proxy_corr: [1.0, 0.45, 0.30],
                        ..Default::default()
                    };
                    // corr 1.0 on channel 0 == true attention-mass ranking
                    let p = make_policy("paged").unwrap();
                    acc += simulate_episode(d, p.as_ref(), &cfg).score;
                }
                row.push(format!("{:.1}", acc / episodes as f64));
            }
            t.row(row);
        }
        println!(
            "\n--- {} (full-cache score {:.1}, prompt {} tokens) ---",
            d.name, d.full_score, d.prompt_len
        );
        print!("{}", t.render());
    }
    let _ = H2oOracle::new(vec![]); // (exported oracle type; per-episode use in sim tests)
    println!(
        "\n* h2o_oracle = block eviction on the NOISELESS attention-mass \
         signal (deployable only with attention-score access, which \
         PagedAttention does not expose — paper §5.2)."
    );
}

fn real_track(prompts: usize) {
    section("Fig 2 (REAL track): sim-1b through the full runtime, vs budget");
    let engine = match Engine::new(artifacts_dir()) {
        Ok(e) => e,
        Err(e) => {
            println!("skipped (run `make artifacts`): {e:#}");
            return;
        }
    };
    let info = engine.manifest.model("sim-1b").unwrap();
    println!("weights: {}", info.weights_src);
    let runner = ModelRunner::new(&engine, "sim-1b", 16).unwrap();
    let plen = 224usize;
    let gen_len = 24usize;
    let budgets = [32usize, 64, 96, 128, 192];
    // Primary metric: full-cache FIDELITY — ROUGE-L over token ids of the
    // generation under eviction vs the full-cache generation for the same
    // prompt (the paper's "<3-5% degradation from Full Cache" claim made
    // directly measurable). Secondary: needle recall accuracy (meaningful
    // only when `make train` produced a model that solves the task).
    for metric in ["fidelity(ROUGE-L vs full)", "recall-acc %"] {
        let mut header = vec!["policy".to_string()];
        header.extend(budgets.iter().map(|b| format!("b={b}")));
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for pol in ALL_POLICIES {
            let mut row = vec![pol.to_string()];
            for &budget in &budgets {
                let mut acc = 0.0;
                for i in 0..prompts {
                    let mut rng = Pcg32::with_stream(500 + i as u64, 77);
                    let frac = 0.1 + 0.75 * rng.f64();
                    let p = recall::make_prompt(&mut rng, plen, frac);
                    if metric.starts_with("fidelity") {
                        let reference =
                            generate(&runner, &p.tokens, 100_000, "full", gen_len);
                        let cand = generate(&runner, &p.tokens, budget, pol, gen_len);
                        acc += paged_eviction::sim::rouge::rouge_l_ids(&cand, &reference);
                    } else {
                        let (_seq, logits) = runner
                            .prefill(&p.tokens, budget, make_policy(pol).unwrap())
                            .unwrap();
                        acc += f64::from(argmax(&logits) == p.answer);
                    }
                }
                if metric.starts_with("fidelity") {
                    row.push(format!("{:.2}", acc / prompts as f64));
                } else {
                    row.push(format!("{:.0}", 100.0 * acc / prompts as f64));
                }
            }
            t.row(row);
        }
        println!("\n{metric} (prompt {plen}, gen {gen_len}):");
        print!("{}", t.render());
    }
}

fn generate(
    runner: &ModelRunner,
    prompt: &[u32],
    budget: usize,
    policy: &str,
    len: usize,
) -> Vec<u32> {
    let (mut seq, logits) = runner
        .prefill(prompt, budget, make_policy(policy).unwrap())
        .unwrap();
    let mut tok = argmax(&logits);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(tok);
        let o = runner.decode_step(&mut seq, tok).unwrap();
        tok = argmax(&o.logits);
    }
    out
}
