//! Figure 2 — Accuracy vs cache budget, five LongBench datasets.
//!
//! Two tracks (DESIGN.md §4/§5):
//!   SIM:  paper-scale budgets (256..4096) on the attention-mass simulator,
//!         five dataset profiles, plus the H2O oracle upper bound. Each
//!         cell's episodes fan out across cores via `simulate_mean`
//!         (thread::scope underneath), which reproduces the historical
//!         serial `seed = e * 7919` schedule bit-for-bit.
//!   REAL: sim-1b through the full runtime (needs `--features xla` +
//!         `make artifacts`) — full-cache fidelity (ROUGE-L vs the
//!         full-cache generation) + needle recall when trained.
//!
//!     cargo bench --bench fig2_accuracy
//!     cargo bench --bench fig2_accuracy -- --track sim --episodes 64

mod common;

use common::{bench_args, section};
use paged_eviction::eviction::{make_policy, REGISTRY};
use paged_eviction::sim::attention_sim::{simulate_mean, SimConfig};
use paged_eviction::sim::datasets::DATASETS;
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::stats::Table;

fn main() {
    let args = bench_args(
        ArgSpec::new("fig2_accuracy", "accuracy vs cache budget (paper Fig. 2)")
            .opt("track", "both", "sim | real | both")
            .opt("episodes", "16", "sim episodes per cell")
            .opt("prompts", "16", "real prompts per cell")
            .flag("oracle", "include the H2O oracle row (sim track)"),
    );
    let track = args.get("track");
    if track == "sim" || track == "both" {
        sim_track(args.get_usize("episodes"), true);
    }
    if track == "real" || track == "both" {
        #[cfg(feature = "xla")]
        real_track(args.get_usize("prompts"));
        #[cfg(not(feature = "xla"))]
        println!(
            "\n(REAL track skipped: built without --features xla; {} prompts requested)",
            args.get_usize("prompts")
        );
    }
}

fn sim_track(episodes: usize, oracle: bool) {
    section("Fig 2 (SIM track): score vs budget, page 16");
    let budgets = [256usize, 512, 1024, 2048, 4096];
    // the full registry — the attention-feedback policies (self_attn,
    // self_attn_token, attention_gate) run on the simulator's TRUTH mass
    // here, the same signal the h2o_oracle row idealizes
    let sweep: Vec<&'static str> = REGISTRY.iter().map(|i| i.name).collect();
    for d in &DATASETS {
        // oracle = paged on the NOISELESS channel-0 signal (corr 1.0)
        let n_rows = sweep.len() + usize::from(oracle);
        let mut header = vec!["policy".to_string()];
        header.extend(budgets.iter().map(|b| format!("b={b}")));
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for pi in 0..n_rows {
            let (name, pol, corr) = if pi < sweep.len() {
                (sweep[pi], sweep[pi], None)
            } else {
                ("h2o_oracle*", "paged", Some([1.0, 0.45, 0.30]))
            };
            let p = make_policy(pol).unwrap();
            let mut row = vec![name.to_string()];
            for &budget in &budgets {
                let mut cfg = SimConfig { budget, ..Default::default() };
                if let Some(c) = corr {
                    cfg.proxy_corr = c;
                }
                // episodes fan out across cores; seed base 0 makes
                // simulate_mean's i*7919 derivation identical to the
                // historical per-episode seeds of this bench
                let r = simulate_mean(d, p.as_ref(), &cfg, episodes);
                row.push(format!("{:.1}", r.score));
            }
            t.row(row);
        }
        println!(
            "\n--- {} (full-cache score {:.1}, prompt {} tokens) ---",
            d.name, d.full_score, d.prompt_len
        );
        print!("{}", t.render());
    }
    println!(
        "\n* h2o_oracle = block eviction on the NOISELESS attention-mass \
         signal (deployable only with attention-score access, which \
         PagedAttention does not expose — paper §5.2)."
    );
}

#[cfg(feature = "xla")]
fn real_track(prompts: usize) {
    use common::artifacts_dir;
    use paged_eviction::runtime::model_runner::argmax;
    use paged_eviction::runtime::{Engine, ModelRunner};
    use paged_eviction::util::rng::Pcg32;
    use paged_eviction::workload::recall;

    section("Fig 2 (REAL track): sim-1b through the full runtime, vs budget");
    let engine = match Engine::new(artifacts_dir()) {
        Ok(e) => e,
        Err(e) => {
            println!("skipped (run `make artifacts`): {e:#}");
            return;
        }
    };
    let info = engine.manifest.model("sim-1b").unwrap();
    println!("weights: {}", info.weights_src);
    let runner = ModelRunner::new(&engine, "sim-1b", 16).unwrap();
    let plen = 224usize;
    let gen_len = 24usize;
    let budgets = [32usize, 64, 96, 128, 192];
    // Primary metric: full-cache FIDELITY — ROUGE-L over token ids of the
    // generation under eviction vs the full-cache generation for the same
    // prompt (the paper's "<3-5% degradation from Full Cache" claim made
    // directly measurable). Secondary: needle recall accuracy (meaningful
    // only when `make train` produced a model that solves the task).
    for metric in ["fidelity(ROUGE-L vs full)", "recall-acc %"] {
        let mut header = vec!["policy".to_string()];
        header.extend(budgets.iter().map(|b| format!("b={b}")));
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for pol in REGISTRY.iter().map(|i| i.name) {
            let mut row = vec![pol.to_string()];
            for &budget in &budgets {
                let mut acc = 0.0;
                for i in 0..prompts {
                    let mut rng = Pcg32::with_stream(500 + i as u64, 77);
                    let frac = 0.1 + 0.75 * rng.f64();
                    let p = recall::make_prompt(&mut rng, plen, frac);
                    if metric.starts_with("fidelity") {
                        let reference =
                            generate(&runner, &p.tokens, 100_000, "full", gen_len);
                        let cand = generate(&runner, &p.tokens, budget, pol, gen_len);
                        acc += paged_eviction::sim::rouge::rouge_l_ids(&cand, &reference);
                    } else {
                        let (_seq, logits) = runner
                            .prefill(&p.tokens, budget, make_policy(pol).unwrap())
                            .unwrap();
                        acc += f64::from(argmax(&logits) == p.answer);
                    }
                }
                if metric.starts_with("fidelity") {
                    row.push(format!("{:.2}", acc / prompts as f64));
                } else {
                    row.push(format!("{:.0}", 100.0 * acc / prompts as f64));
                }
            }
            t.row(row);
        }
        println!("\n{metric} (prompt {plen}, gen {gen_len}):");
        print!("{}", t.render());
    }
}

#[cfg(feature = "xla")]
fn generate(
    runner: &paged_eviction::runtime::ModelRunner,
    prompt: &[u32],
    budget: usize,
    policy: &str,
    len: usize,
) -> Vec<u32> {
    use paged_eviction::runtime::model_runner::argmax;

    let (mut seq, logits) = runner
        .prefill(prompt, budget, make_policy(policy).unwrap())
        .unwrap();
    let mut tok = argmax(&logits);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(tok);
        let o = runner.decode_step(&mut seq, tok).unwrap();
        tok = argmax(&o.logits);
    }
    out
}
