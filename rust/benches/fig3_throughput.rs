//! Figure 3 — serving throughput (a-c) and time-per-output-token (d) vs
//! cache budget, per model and eviction policy, plus the §5.4 ratio lines
//! and the fragmentation/overhead counters behind Limitations 1 & 4.
//!
//! Closed-loop setup scaled from the paper's (in 1024 / out 8192 / 64
//! concurrent on A100) to this single-core CPU PJRT testbed:
//! in 384 / out 448 / `--concurrency` round-robin, so Full Cache
//! climbs into the 1024-token bucket while budgeted policies stay small.
//!
//!     cargo bench --bench fig3_throughput
//!     cargo bench --bench fig3_throughput -- --models sim-1b --gen 96

mod common;

use std::time::Instant;

use common::{artifacts_dir, bench_args, section};
use paged_eviction::api::RequestBuilder;
use paged_eviction::runtime::Engine;
use paged_eviction::scheduler::{default_workers, MultiEngine, Request, SchedConfig, Scheduler};
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::util::stats::Table;
use paged_eviction::workload::recall;

struct Cell {
    tok_s: f64,
    tpot_ms: f64,
    updates_per_token: f64,
    /// Sequences preempted (watermark crossed or shared arena ran dry).
    preemptions: u64,
    /// Preemption readmissions served by restoring a swap-to-host
    /// snapshot; `preemptions - swap_restores` went the recompute path.
    /// (The PJRT backend opts out of snapshots today, so this column
    /// reads 0 until the device-resident cache lands — the sim-backed
    /// tests in tests/swap_preempt.rs exercise the live path.)
    swap_restores: u64,
    /// High-water fragmented pages across the cell's sequences
    /// (`CacheStats::peak_partial_blocks`).
    partial_blocks_max: usize,
    /// High-water physical block footprint across the cell's sequences
    /// (`CacheStats::peak_live_blocks`).
    peak_blocks_max: usize,
    /// Prompt blocks served from the prefix index across the cell.
    /// (Reads 0 on the PJRT backend until it implements prefix caching —
    /// the column exists so the first device-resident-cache PR lights it
    /// up without touching the bench.)
    prefix_hits: u64,
    /// Copy-on-write page copies across the cell (nonzero only for
    /// token-killing policies, which hole-punch shared pages).
    cow_copies: u64,
}

#[allow(clippy::too_many_arguments)] // bench driver: one flag per knob
fn run_cell(
    engine: &Engine,
    model: &str,
    policy: &str,
    budget: usize,
    n_req: usize,
    prompt_len: usize,
    gen: usize,
    concurrency: usize,
    arena_blocks: usize,
    swap_bytes: usize,
    prefix_cache: bool,
) -> anyhow::Result<Cell> {
    let mut sched = Scheduler::new(
        engine,
        SchedConfig {
            model: model.into(),
            page_size: 16,
            max_concurrency: concurrency,
            max_live_blocks: arena_blocks,
            swap_bytes,
            prefix_cache,
            ..SchedConfig::default()
        },
    )?;
    let mut rng = Pcg32::with_stream(99, budget as u64);
    for i in 0..n_req {
        let frac = 0.2 + 0.6 * rng.f64();
        let p = recall::make_prompt(&mut rng, prompt_len, frac);
        let mut req = Request::new(i as u64 + 1, p.tokens, gen);
        req.budget = budget;
        req.policy = policy.to_string();
        sched.submit(req);
    }
    let outs = sched.run_to_completion()?;
    let mut updates = 0u64;
    let mut written = 0u64;
    let mut partial_max = 0usize;
    let mut peak_blocks = 0usize;
    let mut cow = 0u64;
    for o in &outs {
        updates += o.cache_stats.table_updates + o.cache_stats.mask_updates;
        written += o.cache_stats.tokens_written;
        cow += o.cache_stats.cow_copies;
        // true high-water marks, tracked by the cache itself
        partial_max = partial_max.max(o.cache_stats.peak_partial_blocks as usize);
        peak_blocks = peak_blocks.max(o.cache_stats.peak_live_blocks as usize);
    }
    let mut tpot = sched.tpot.clone();
    Ok(Cell {
        tok_s: sched.throughput_tok_s(),
        tpot_ms: if tpot.is_empty() { 0.0 } else { tpot.pctl(50.0) },
        updates_per_token: updates as f64 / written.max(1) as f64,
        preemptions: sched.preemptions,
        swap_restores: sched.swap_restores,
        partial_blocks_max: partial_max,
        peak_blocks_max: peak_blocks,
        prefix_hits: sched.prefix_hit_blocks,
        cow_copies: cow,
    })
}

fn main() {
    let args = bench_args(
        ArgSpec::new("fig3_throughput", "throughput + TPOT vs budget (paper Fig. 3)")
            .opt("models", "sim-1b,sim-3b,sim-8b", "models to sweep")
            .opt("policies", "full,streaming,inverse_key_norm,keydiff,paged", "policies")
            .opt("budgets", "64,128,256", "token budgets (full ignores)")
            .opt("requests", "2", "requests per cell")
            .opt("prompt-len", "384", "prompt tokens")
            .opt("gen", "256", "output tokens per request")
            .opt("concurrency", "2", "concurrent sequences")
            .opt("arena-blocks", "100000", "shared arena capacity in blocks \
                 (shrink to exercise preemption under memory pressure)")
            .opt("swap-bytes", "67108864", "host swap pool byte cap \
                 (0 = recompute-only preemption)")
            .opt("prefix-cache", "on", "refcounted prompt-prefix sharing \
                 across requests (on|off). NOTE: the PJRT backend does not \
                 implement prefix caching yet (ROADMAP), so hit/cow read 0 \
                 here until it does — the sim-backed scheduler paths and \
                 `schedule` CLI exercise the live feature")
            .opt("workers", &default_workers().to_string(), "scheduler worker \
                 threads for the sim-backed multi-worker section (per-worker \
                 utilization + aggregate tok/s over ONE shared arena). The \
                 PJRT cells above stay single-threaded — that runner is \
                 thread-pinned; 1 skips the section"),
    );
    let engine = Engine::new(artifacts_dir()).expect("make artifacts first");
    let models = args.get_list("models");
    let policies = args.get_list("policies");
    let budgets = args.get_usize_list("budgets");
    let n_req = args.get_usize("requests");
    let plen = args.get_usize("prompt-len");
    let gen = args.get_usize("gen");
    let conc = args.get_usize("concurrency");
    let arena_blocks = args.get_usize("arena-blocks");
    let swap_bytes = args.get_usize("swap-bytes");
    let prefix_cache = args.get("prefix-cache") != "off";

    println!(
        "setup: {n_req} reqs x (in {plen} + out {gen}), {conc} concurrent, page 16 \
         (paper setup scaled: in 1024 / out 8192 / 64 concurrent)"
    );

    for model in &models {
        // Global warmup: compile every bucket a cell can touch (one-time,
        // cached in the Engine) so PJRT compilation never lands in a timed
        // cell. Full cache walks the whole growth ladder; one budgeted run
        // covers the small buckets.
        eprintln!("[warmup {model}]");
        for (policy, budget, wgen) in
            [("full", 100_000usize, gen), ("paged", budgets[0], 2 * 16)]
        {
            let _ =
                run_cell(&engine, model, policy, budget, 1, plen, wgen, 1, 100_000, 0, false)
                    .expect("warmup failed");
        }
        section(&format!("Fig 3 ({model}): throughput (tok/s) vs budget"));
        let mut header = vec!["policy".to_string()];
        header.extend(budgets.iter().map(|b| format!("b={b}")));
        header.push("tpot_ms@mid".into());
        header.push("upd/tok".into());
        header.push("partial@mid".into());
        header.push("blocks@mid".into());
        header.push("preempt".into());
        header.push("swap".into());
        header.push("hit".into());
        header.push("cow".into());
        let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let mut full_mid = 0.0;
        let mut paged_mid = 0.0;
        let mut stream_mid = 0.0;
        let mut unstr_mid = 0.0;
        for policy in &policies {
            let mut row = vec![policy.to_string()];
            let mut mid_cell: Option<Cell> = None;
            for (bi, &budget) in budgets.iter().enumerate() {
                // best of 2 runs: this vCPU testbed has double-digit-percent
                // steal-time jitter; max-throughput-of-N is the standard
                // noisy-testbed protocol
                let a = run_cell(
                    &engine, model, policy, budget, n_req, plen, gen, conc, arena_blocks,
                    swap_bytes, prefix_cache,
                )
                .expect("cell failed");
                let b = run_cell(
                    &engine, model, policy, budget, n_req, plen, gen, conc, arena_blocks,
                    swap_bytes, prefix_cache,
                )
                .expect("cell failed");
                let cell = if a.tok_s >= b.tok_s { a } else { b };
                row.push(format!("{:.0}", cell.tok_s));
                if bi == budgets.len() / 2 {
                    mid_cell = Some(cell);
                }
            }
            let mid = mid_cell.unwrap();
            match policy.as_str() {
                "full" => full_mid = mid.tok_s,
                "paged" => paged_mid = mid.tok_s,
                "streaming" => stream_mid = mid.tok_s,
                "inverse_key_norm" => unstr_mid = mid.tok_s,
                _ => {}
            }
            row.push(format!("{:.2}", mid.tpot_ms));
            row.push(format!("{:.3}", mid.updates_per_token));
            row.push(format!("{}", mid.partial_blocks_max));
            row.push(format!("{}", mid.peak_blocks_max));
            row.push(format!("{}", mid.preemptions));
            row.push(format!("{}", mid.swap_restores));
            row.push(format!("{}", mid.prefix_hits));
            row.push(format!("{}", mid.cow_copies));
            t.row(row);
        }
        print!("{}", t.render());
        if paged_mid > 0.0 {
            println!("§5.4 ratios at mid budget:");
            if full_mid > 0.0 {
                println!(
                    "  paged vs full cache:   {:+.1}%  (paper: +37%)",
                    100.0 * (paged_mid / full_mid - 1.0)
                );
            }
            if stream_mid > 0.0 {
                println!(
                    "  paged vs streaming:    {:+.1}%  (paper: +4.1%)",
                    100.0 * (paged_mid / stream_mid - 1.0)
                );
            }
            if unstr_mid > 0.0 {
                println!(
                    "  paged vs inverse-key:  {:+.1}%  (paper: +39%)",
                    100.0 * (paged_mid / unstr_mid - 1.0)
                );
            }
        }
    }
    println!(
        "\nFig 3(d) TPOT: the tpot_ms@mid column above, per model \
         (paper: paged ~10-12% below full cache)."
    );

    let workers = args.get_usize("workers").max(1);
    if workers > 1 {
        multi_worker_section(
            workers,
            budgets[budgets.len() / 2],
            n_req,
            plen,
            gen,
            conc,
            arena_blocks,
            swap_bytes,
            prefix_cache,
        );
    }
}

/// Sim-backed multi-worker leg: the same closed-loop workload through the
/// engine's worker shards (one shared arena/swap pool/prefix index), with
/// the per-worker utilization breakdown the single-scheduler cells cannot
/// show. Aggregate tok/s here is comparable across `--workers` values —
/// outputs are bit-identical at any count, so only wall time moves.
#[allow(clippy::too_many_arguments)] // bench driver: one flag per knob
fn multi_worker_section(
    workers: usize,
    budget: usize,
    n_req: usize,
    plen: usize,
    gen: usize,
    conc: usize,
    arena_blocks: usize,
    swap_bytes: usize,
    prefix_cache: bool,
) {
    section(&format!(
        "multi-worker engine (sim backend, {workers} workers, paged@b={budget}): \
         per-worker utilization"
    ));
    let total_reqs = n_req.max(2) * workers;
    let mut engine = MultiEngine::new_sim(SchedConfig {
        model: "sim".into(),
        page_size: 16,
        max_concurrency: conc,
        max_live_blocks: arena_blocks,
        swap_bytes,
        prefix_cache,
        workers,
        ..SchedConfig::default()
    });
    let mut rng = Pcg32::with_stream(99, budget as u64);
    let t0 = Instant::now();
    for _ in 0..total_reqs {
        let frac = 0.2 + 0.6 * rng.f64();
        let p = recall::make_prompt(&mut rng, plen, frac);
        engine
            .submit_builder(
                RequestBuilder::new(p.tokens)
                    .max_new_tokens(gen)
                    .policy("paged")
                    .budget(budget),
            )
            .expect("submit");
    }
    let outs = engine.run_to_completion();
    let elapsed = t0.elapsed().as_secs_f64();
    let decoded: usize = outs.iter().map(|o| o.tokens.len()).sum();
    let (report, _backends) = engine.shutdown(std::time::Duration::from_secs(10));
    let mut t = Table::new(&["worker", "rounds", "busy", "util%", "tokens", "preempt"]);
    for w in &report.workers {
        t.row(vec![
            format!("{}", w.worker),
            format!("{}", w.rounds),
            format!("{}", w.busy_rounds),
            format!("{:.0}", 100.0 * w.utilization()),
            format!("{}", w.decoded_tokens),
            format!("{}", w.preemptions),
        ]);
    }
    print!("{}", t.render());
    println!(
        "aggregate: {total_reqs} reqs, {:.0} tok/s over {workers} workers \
         (steals {}, cross preempts {})",
        decoded as f64 / elapsed.max(1e-9),
        report.steals,
        report.cross_preempts,
    );
}
