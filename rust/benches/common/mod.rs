//! Shared bench plumbing (criterion is not in the offline vendor set; these
//! benches are plain binaries with `harness = false` that print the
//! paper-figure tables to stdout).

use paged_eviction::util::args::{ArgSpec, Args};

/// Parse bench args after the `--` separator cargo-bench passes through.
/// Also tolerates the `--bench` flag cargo injects.
pub fn bench_args(spec: ArgSpec) -> Args {
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--bench")
        .collect();
    match spec.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

/// Artifact directory for the PJRT-backed sections (unused when built
/// without the `xla` feature).
#[allow(dead_code)]
pub fn artifacts_dir() -> String {
    std::env::var("PAGED_EVICTION_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

/// Paper-style section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
