//! Visual trace of each eviction policy's cache behaviour — the ASCII
//! rendition of the paper's Figures 1, 5 and 6.
//!
//!     cargo run --release --example policy_compare
//!     cargo run --release --example policy_compare -- --policy streaming
//!
//! Each line is one decode step; each page is rendered as its occupancy
//! ('#' full, digits = live tokens, '.' freed slot). Structured eviction
//! (paged) drops whole pages; StreamingLLM drains the oldest page token by
//! token; unstructured baselines punch holes everywhere.

use anyhow::Result;
use paged_eviction::eviction::{make_policy, Decision, ALL_POLICIES};
use paged_eviction::kvcache::SeqCache;
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::rng::Pcg32;

fn render(cache: &SeqCache) -> String {
    let mut s = String::new();
    for blk in cache.blocks() {
        let live = blk.live_count();
        if live == cache.block_size() && blk.fill == cache.block_size() {
            s.push('#');
        } else if blk.fill < cache.block_size() && !blk.is_partial() {
            s.push_str(&format!("{:x}", blk.fill)); // growing newest page
        } else {
            // fragmented page: show live count
            s.push_str(&format!("{:x}", live));
        }
        s.push(' ');
    }
    s
}

fn trace(policy_name: &str, steps: usize) -> Result<()> {
    let bs = 8usize;
    let budget = 4 * bs;
    let mut rng = Pcg32::new(9);
    let policy = make_policy(policy_name)?;
    let mut cache = SeqCache::new(bs, 12);
    let pre: Vec<(u32, [f32; 3])> = (0..budget as u32)
        .map(|i| (i, [rng.f32(), rng.f32(), rng.f32()]))
        .collect();
    cache.load_prefill(&pre, budget as u32);
    println!(
        "\n== {policy_name} (page {bs}, budget {budget} tokens = {} pages) ==",
        budget / bs
    );
    println!("step  0: {}", render(&cache));
    for step in 1..=steps {
        if !cache.ensure_block() {
            cache.grow(cache.capacity_blocks() + 2);
            cache.ensure_block();
        }
        cache.append([rng.f32(), rng.f32(), rng.f32()]);
        match policy.post_append(&cache, budget) {
            Decision::Keep => {}
            Decision::EvictBlock(i) => cache.evict_block(i),
            Decision::KillTokens(ts) => {
                for (bi, off) in ts {
                    cache.kill_token(bi, off);
                }
            }
        }
        println!("step {step:2}: {}", render(&cache));
    }
    let st = &cache.stats;
    println!(
        "-> live {} | partial pages {} | whole-page evictions {} | \
         table updates {} | per-token mask updates {}",
        cache.live_tokens(),
        cache.partial_blocks(),
        st.blocks_evicted,
        st.table_updates,
        st.mask_updates,
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = ArgSpec::new("policy_compare", "ASCII eviction traces (Figs 1/5/6)")
        .opt("policy", "all", "policy name or 'all'")
        .opt("steps", "20", "decode steps to trace")
        .parse_or_exit(1);
    let steps = args.get_usize("steps");
    if args.get("policy") == "all" {
        for p in ALL_POLICIES {
            trace(p, steps)?;
        }
    } else {
        trace(args.get("policy"), steps)?;
    }
    println!(
        "\nLegend: '#' full page, hex digit = live tokens in a partially \
         filled/fragmented page. PagedEviction keeps every page either full \
         or newest-growing; unstructured baselines accumulate fragmented \
         pages they cannot free (paper Figs 5/6)."
    );
    Ok(())
}
