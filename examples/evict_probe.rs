//! Diagnostic: decode-step timing + RSS tracking (leak hunting).
use paged_eviction::eviction::make_policy;
use paged_eviction::runtime::{Engine, ModelRunner};
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::workload::recall;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "decode".into());
    let engine = Engine::new("artifacts").unwrap();
    let runner = ModelRunner::new(&engine, "sim-1b", 16).unwrap();
    let mut rng = Pcg32::new(5);
    let p = recall::make_prompt(&mut rng, 384, 0.5);
    let (mut seq, logits) = runner.prefill(&p.tokens, 100_000, make_policy("full").unwrap()).unwrap();
    let mut tok = argmax(&logits);
    println!("start rss {:.1} MB, mode={mode}", rss_mb());
    match mode.as_str() {
        "decode" => {
            for i in 0..600 {
                let o = runner.decode_step(&mut seq, tok).unwrap();
                tok = argmax(&o.logits);
                if i % 100 == 0 { println!("step {i}: rss {:.1} MB", rss_mb()); }
            }
        }
        "exec-raw" => {
            // raw execute of the same decode graph with constant inputs,
            // WITHOUT to_literal_sync/to_tuple
            use paged_eviction::runtime::engine::{lit_f32, lit_i32, scalar_i32};
            let g = engine.manifest.decode_graph("sim-1b", 16, 512).unwrap();
            let exe = engine.executable(g).unwrap();
            let w = engine.weights("sim-1b").unwrap();
            let nb = g.n_blocks;
            let info = engine.manifest.model("sim-1b").unwrap();
            let cache_data = vec![0.0f32; info.n_layers*info.n_kv_heads*nb*16*info.d_head];
            let shape = [info.n_layers, info.n_kv_heads, nb, 16, info.d_head];
            let _ = (&exe, &w);
            for i in 0..600 {
                let inputs = [
                    scalar_i32(1), scalar_i32(5),
                    lit_f32(&cache_data, &shape).unwrap(),
                    lit_f32(&cache_data, &shape).unwrap(),
                    lit_i32(&vec![0i32; nb], &[nb]).unwrap(),
                    scalar_i32(6),
                    lit_f32(&vec![1.0; nb*16], &[nb, 16]).unwrap(),
                ];
                let parts = engine.run(g, &inputs).unwrap();
                std::hint::black_box(parts.len());
                if i % 100 == 0 { println!("iter {i}: rss {:.1} MB", rss_mb()); }
            }
        }
        _ => panic!("mode?"),
    }
    println!("end rss {:.1} MB", rss_mb());
}
