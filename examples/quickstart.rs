//! Quickstart: load the AOT artifacts, run one generation under
//! PagedEviction, and print what the cache did.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end path through all three layers: the
//! Pallas paged-attention kernel (lowered to HLO at build time), the JAX
//! model graphs, and the Rust coordinator with its paged KV cache.

use anyhow::Result;
use paged_eviction::eviction::make_policy;
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::{Engine, ModelRunner};
use paged_eviction::util::rng::Pcg32;
use paged_eviction::workload::recall;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // A 96-token associative-recall prompt with the needle 25% in.
    let mut rng = Pcg32::new(42);
    let prompt = recall::make_prompt(&mut rng, 96, 0.25);
    println!(
        "prompt: {} tokens, needle pair at positions {:?}, answer token {}",
        prompt.tokens.len(),
        prompt.needle,
        prompt.answer
    );

    // Serve it with a 64-token KV budget under the paper's policy.
    let runner = ModelRunner::new(&engine, "sim-1b", 16)?;
    let (mut seq, logits) = runner.prefill(&prompt.tokens, 64, make_policy("paged")?)?;
    println!(
        "prefill: kept {}/{} tokens in {} pages",
        seq.cache.live_tokens(),
        prompt.tokens.len(),
        seq.cache.n_blocks()
    );

    let mut tok = argmax(&logits);
    print!("generated:");
    for _ in 0..8 {
        print!(" {tok}");
        let out = runner.decode_step(&mut seq, tok)?;
        tok = argmax(&out.logits);
    }
    println!();

    let st = &seq.cache.stats;
    println!(
        "cache: live={} blocks={} (0 partial: structured eviction never \
         fragments) | evicted {} whole pages, {} table updates, {} mask updates",
        seq.cache.live_tokens(),
        seq.cache.n_blocks(),
        st.blocks_evicted,
        st.table_updates,
        st.mask_updates,
    );
    println!("done — see examples/serve_e2e.rs for the full serving driver");
    Ok(())
}
