//! LongBench-style accuracy evaluation — both tracks (DESIGN.md §4):
//!
//!  * REAL track: the trained sim-1b model answers associative-recall
//!    prompts through the full runtime; accuracy vs cache budget per
//!    eviction policy (needle-QA stand-in, run after `make train`).
//!  * SIM track: the attention-mass simulator sweeps the paper's five
//!    LongBench datasets at the paper's budgets.
//!
//!     cargo run --release --example longbench_eval -- --track real
//!     cargo run --release --example longbench_eval -- --track sim

use anyhow::Result;
use paged_eviction::eviction::{make_policy, ALL_POLICIES};
use paged_eviction::runtime::model_runner::argmax;
use paged_eviction::runtime::{Engine, ModelRunner};
use paged_eviction::sim::{self, SimConfig};
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::util::stats::Table;
use paged_eviction::workload::recall;

fn main() -> Result<()> {
    let args = ArgSpec::new("longbench_eval", "accuracy vs cache budget")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("track", "real", "real | sim")
        .opt("prompts", "40", "real track: prompts per cell")
        .opt("prompt-len", "224", "real track: prompt tokens")
        .opt("budgets", "", "comma list (default per track)")
        .parse_or_exit(1);
    match args.get("track") {
        "real" => real_track(&args),
        "sim" => sim_track(&args),
        t => anyhow::bail!("unknown track {t:?}"),
    }
}

fn real_track(args: &paged_eviction::util::args::Args) -> Result<()> {
    let engine = Engine::new(args.get("artifacts"))?;
    let info = engine.manifest.model("sim-1b")?;
    println!(
        "REAL track: sim-1b ({}) needle recall, prompt len {}",
        info.weights_src,
        args.get_usize("prompt-len")
    );
    if !info.weights_src.contains("trained") {
        println!("NOTE: weights are untrained — run `make train` for meaningful accuracy");
    }
    let budgets: Vec<usize> = if args.get("budgets").is_empty() {
        vec![32, 64, 96, 128, 192]
    } else {
        args.get_usize_list("budgets")
    };
    let runner = ModelRunner::new(&engine, "sim-1b", 16)?;
    let n = args.get_usize("prompts");
    let plen = args.get_usize("prompt-len");

    let mut header = vec!["policy".to_string()];
    header.extend(budgets.iter().map(|b| format!("b={b}")));
    let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for policy in ALL_POLICIES {
        let mut row = vec![policy.to_string()];
        for &budget in &budgets {
            let mut hit = 0usize;
            for i in 0..n {
                let mut rng = Pcg32::with_stream(1000 + i as u64, budget as u64);
                let frac = 0.15 + 0.7 * rng.f64();
                let p = recall::make_prompt(&mut rng, plen, frac);
                let (mut seq, logits) =
                    runner.prefill(&p.tokens, budget, make_policy(policy)?)?;
                // answer = first generated token
                let tok = argmax(&logits);
                hit += usize::from(tok == p.answer);
                // run a couple of decode steps to exercise decode eviction
                let mut t = tok;
                for _ in 0..2 {
                    let o = runner.decode_step(&mut seq, t)?;
                    t = argmax(&o.logits);
                }
            }
            row.push(format!("{:.0}%", 100.0 * hit as f64 / n as f64));
        }
        table.row(row);
    }
    print!("{}", table.render());
    println!("(full-cache row is the model's ceiling; see EXPERIMENTS.md)");
    Ok(())
}

fn sim_track(args: &paged_eviction::util::args::Args) -> Result<()> {
    let budgets: Vec<usize> = if args.get("budgets").is_empty() {
        vec![256, 512, 1024, 2048, 4096]
    } else {
        args.get_usize_list("budgets")
    };
    println!("SIM track: paper-scale budgets, 5 LongBench-shaped datasets");
    for d in &sim::datasets::DATASETS {
        let mut header = vec!["policy".to_string()];
        header.extend(budgets.iter().map(|b| format!("b={b}")));
        let mut table = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for policy in ALL_POLICIES {
            let p = make_policy(policy)?;
            let mut row = vec![policy.to_string()];
            for &budget in &budgets {
                let r = sim::attention_sim::simulate_mean(
                    d,
                    p.as_ref(),
                    &SimConfig { budget, ..Default::default() },
                    16,
                );
                row.push(format!("{:.1}", r.score));
            }
            table.row(row);
        }
        println!("\n--- {} (full-cache score {:.1}) ---", d.name, d.full_score);
        print!("{}", table.render());
    }
    Ok(())
}
