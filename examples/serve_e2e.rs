//! End-to-end serving driver (the EXPERIMENTS.md §E2E run).
//!
//! Spins up the real stack — engine thread + TCP JSON-lines frontend —
//! then drives it with concurrent client connections sending
//! associative-recall prompts, and reports the paper's serving metrics
//! (throughput, TPOT, latency percentiles) plus task accuracy.
//!
//!     make artifacts && make train   # trained weights recommended
//!     cargo run --release --example serve_e2e -- --requests 24 --concurrency 8
//!
//! All layers compose here: Pallas kernel -> JAX graphs -> PJRT -> paged
//! cache + eviction -> continuous batcher -> TCP protocol -> client.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;
use paged_eviction::scheduler::SchedConfig;
use paged_eviction::server::serve::{serve_forever, spawn_engine, ServeOpts};
use paged_eviction::util::args::ArgSpec;
use paged_eviction::util::json::Json;
use paged_eviction::util::rng::Pcg32;
use paged_eviction::util::stats::{Histogram, Table};
use paged_eviction::workload::recall;

fn main() -> Result<()> {
    let args = ArgSpec::new("serve_e2e", "end-to-end serving driver")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("model", "sim-1b", "model")
        .opt("requests", "24", "total requests")
        .opt("concurrency", "8", "client connections")
        .opt("prompt-len", "192", "prompt tokens")
        .opt("max-new-tokens", "16", "generation length per request")
        .opt("budget", "128", "KV budget per request")
        .opt("policy", "paged", "eviction policy")
        .parse_or_exit(1);

    let cfg = SchedConfig {
        model: args.get("model").into(),
        page_size: 16,
        max_concurrency: args.get_usize("concurrency"),
        max_live_blocks: 4096,
        ..SchedConfig::default()
    };
    let (handle, _join) = spawn_engine(args.get("artifacts").into(), cfg)?;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        let _ = serve_forever(listener, handle, ServeOpts::default());
    });

    let n_req = args.get_usize("requests");
    let conc = args.get_usize("concurrency");
    let plen = args.get_usize("prompt-len");
    let gen = args.get_usize("max-new-tokens");
    let budget = args.get_usize("budget");
    let policy = args.get("policy").to_string();

    println!(
        "e2e: {n_req} requests x (prompt {plen} + gen {gen}) via {conc} \
         connections, policy={policy}, budget={budget}"
    );

    let results = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..conc {
        let results = results.clone();
        let policy = policy.clone();
        let my_n = n_req / conc + usize::from(c < n_req % conc);
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Pcg32::with_stream(7, c as u64);
            let stream = TcpStream::connect(addr)?;
            let mut w = stream.try_clone()?;
            let mut r = BufReader::new(stream);
            for i in 0..my_n {
                let frac = 0.2 + 0.6 * rng.f64();
                let p = recall::make_prompt(&mut rng, plen, frac);
                let req = Json::obj(vec![
                    ("id", Json::num((c * 1000 + i + 1) as f64)),
                    (
                        "prompt",
                        Json::Arr(p.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                    ),
                    ("max_new_tokens", Json::num(gen as f64)),
                    ("budget", Json::num(budget as f64)),
                    ("policy", Json::str(policy.as_str())),
                ]);
                let sent = Instant::now();
                writeln!(w, "{}", req.to_string())?;
                let mut line = String::new();
                r.read_line(&mut line)?;
                let resp = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
                let latency = sent.elapsed().as_secs_f64();
                let first = resp
                    .get("tokens")
                    .and_then(|t| t.as_arr())
                    .and_then(|a| a.first())
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0) as u32;
                let ttft = resp.get("ttft_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let tpot = resp.get("tpot_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                results.lock().unwrap().push((
                    latency,
                    ttft,
                    tpot,
                    first == p.answer,
                    plen + gen,
                ));
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked")?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let results = results.lock().unwrap();
    let mut lat = Histogram::new();
    let mut ttft = Histogram::new();
    let mut tpot = Histogram::new();
    let mut correct = 0usize;
    let mut tokens = 0usize;
    for &(l, tf, tp, ok, toks) in results.iter() {
        lat.add(l * 1e3);
        ttft.add(tf);
        tpot.add(tp);
        correct += usize::from(ok);
        tokens += toks;
    }
    println!("\n== E2E serving report ==");
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["requests".into(), results.len().to_string()]);
    t.row(vec!["wall time (s)".into(), format!("{wall:.2}")]);
    t.row(vec![
        "throughput (tok/s, in+out)".into(),
        format!("{:.1}", tokens as f64 / wall),
    ]);
    t.row(vec![
        "request rate (req/s)".into(),
        format!("{:.2}", results.len() as f64 / wall),
    ]);
    t.row(vec!["latency".into(), lat.report("ms")]);
    t.row(vec!["ttft".into(), ttft.report("ms")]);
    t.row(vec!["tpot".into(), tpot.report("ms")]);
    t.row(vec![
        "recall accuracy".into(),
        format!("{:.1}% ({}/{})", 100.0 * correct as f64 / results.len() as f64,
                correct, results.len()),
    ]);
    print!("{}", t.render());
    Ok(())
}
