"""L2 correctness: model graphs, the decode-vs-prefill golden consistency
check that validates the whole paged-cache ABI, and weight serialization."""

import os
import tempfile

import numpy as np
import pytest
import jax.numpy as jnp

from compile import configs, model

CFG = configs.SIM_1B


def prefix_mask(nb, b, n):
    return jnp.asarray(
        (np.arange(nb * b) < n).astype(np.float32).reshape(nb, b)
    )


@pytest.fixture(scope="module")
def weights():
    return model.init_weights(CFG)


@pytest.fixture(scope="module")
def flat(weights):
    return model.flatten_weights(CFG, weights)


def _paged_cache_from_prefill(k, v, n, nb, b):
    """Host-side pack, exactly as rust/src/runtime does it: token t of the
    retained prefix goes to physical slot (t//B, t%B)."""
    l, hkv, p, dh = k.shape
    kc = np.zeros((l, hkv, nb, b, dh), np.float32)
    vc = np.zeros_like(kc)
    kn, vn = np.asarray(k), np.asarray(v)
    for t in range(n):
        kc[:, :, t // b, t % b] = kn[:, :, t]
        vc[:, :, t // b, t % b] = vn[:, :, t]
    return jnp.asarray(kc), jnp.asarray(vc)


class TestPrefill:
    def test_shapes(self, flat):
        p = 32
        toks = jnp.zeros((p,), jnp.int32)
        lg, k, v, sc = model.prefill_fn(CFG, toks, jnp.int32(p), *flat)
        assert lg.shape == (CFG.vocab_size,)
        assert k.shape == (CFG.n_layers, CFG.n_kv_heads, p, CFG.d_head)
        assert v.shape == k.shape
        assert sc.shape == (3, CFG.n_layers, p)

    def test_padding_invariance(self, flat):
        """Logits at `length` must not depend on pad tokens after it."""
        rng = np.random.default_rng(0)
        toks = rng.integers(0, CFG.vocab_size, size=32).astype(np.int32)
        n = 20
        a = model.prefill_fn(CFG, jnp.asarray(toks), jnp.int32(n), *flat)[0]
        toks2 = toks.copy()
        toks2[n:] = (toks2[n:] + 7) % CFG.vocab_size
        b = model.prefill_fn(CFG, jnp.asarray(toks2), jnp.int32(n), *flat)[0]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_pallas_vs_jnp_path(self, flat):
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 32), jnp.int32)
        a = model.prefill_fn(CFG, toks, jnp.int32(30), *flat, use_pallas=True)
        b = model.prefill_fn(CFG, toks, jnp.int32(30), *flat, use_pallas=False)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-5)


class TestDecodePrefillConsistency:
    """The golden test: stepping the decode graph through the paged cache
    must reproduce the prefill logits for the same prefix. This exercises
    RoPE positions, cache scatter, block tables, masks — the entire ABI."""

    @pytest.mark.parametrize("b", [8, 16])
    def test_stepwise_equals_prefill(self, flat, b):
        rng = np.random.default_rng(2)
        total, start = 28, 20
        nb = 8
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 32), jnp.int32)
        _, k, v, _ = model.prefill_fn(CFG, toks, jnp.int32(start), *flat)
        kc, vc = _paged_cache_from_prefill(k, v, start, nb, b)
        tbl = jnp.arange(nb, dtype=jnp.int32)
        for t in range(start, total):
            lg, kc, vc, sc = model.decode_fn(
                CFG, toks[t], jnp.int32(t), kc, vc, tbl,
                jnp.int32(t), prefix_mask(nb, b, t + 1), *flat,
            )
            want = model.prefill_fn(CFG, toks, jnp.int32(t + 1), *flat)[0]
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(want), rtol=2e-4, atol=2e-5,
                err_msg=f"step t={t}",
            )

    def test_decode_scores_match_prefill_scores(self, flat):
        """Channels 0/1 of the decode score output must equal the prefill
        score kernel's value for the same token."""
        rng = np.random.default_rng(3)
        b, nb, start = 8, 8, 24
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 32), jnp.int32)
        _, k, v, _ = model.prefill_fn(CFG, toks, jnp.int32(start), *flat)
        kc, vc = _paged_cache_from_prefill(k, v, start, nb, b)
        tbl = jnp.arange(nb, dtype=jnp.int32)
        _, kc, vc, sc = model.decode_fn(
            CFG, toks[start], jnp.int32(start), kc, vc, tbl,
            jnp.int32(start), prefix_mask(nb, b, start + 1), *flat,
        )
        _, _, _, psc = model.prefill_fn(CFG, toks, jnp.int32(start + 1), *flat)
        np.testing.assert_allclose(
            np.asarray(sc)[:2], np.asarray(psc)[:2, :, start],
            rtol=1e-3, atol=1e-5,
        )

    def test_block_table_shuffle_invariance(self, flat):
        """Decoding with physically-scattered blocks + matching table must
        equal the identity layout — eviction's zero-copy table shuffle."""
        rng = np.random.default_rng(4)
        b, nb, start = 8, 8, 24
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 32), jnp.int32)
        _, k, v, _ = model.prefill_fn(CFG, toks, jnp.int32(start), *flat)
        kc, vc = _paged_cache_from_prefill(k, v, start, nb, b)
        ident = jnp.arange(nb, dtype=jnp.int32)
        lg0, *_ = model.decode_fn(
            CFG, toks[start], jnp.int32(start), kc, vc, ident,
            jnp.int32(start), prefix_mask(nb, b, start + 1), *flat,
        )
        perm = np.asarray([3, 1, 0, 2, 4, 5, 7, 6])
        kc2 = jnp.asarray(np.asarray(kc)[:, :, perm])
        vc2 = jnp.asarray(np.asarray(vc)[:, :, perm])
        inv = np.argsort(perm).astype(np.int32)
        # new token goes to logical block 3 = physical perm-slot of block 3
        phys_block = int(inv[start // b])
        slot = phys_block * b + start % b
        lg1, *_ = model.decode_fn(
            CFG, toks[start], jnp.int32(start), kc2, vc2, jnp.asarray(inv),
            jnp.int32(slot), prefix_mask(nb, b, start + 1), *flat,
        )
        np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                                   rtol=1e-4, atol=1e-5)

    def test_evicted_block_is_invisible(self, flat):
        """After dropping a middle block (table shrink + n_valid shrink),
        logits must equal attention over only the retained tokens."""
        rng = np.random.default_rng(5)
        b, nb = 8, 8
        start = 24  # 3 full blocks
        toks = jnp.asarray(rng.integers(0, CFG.vocab_size, 32), jnp.int32)
        _, k, v, _ = model.prefill_fn(CFG, toks, jnp.int32(start), *flat)
        kc, vc = _paged_cache_from_prefill(k, v, start, nb, b)
        # Evict logical block 1 (tokens 8..15): table [0,2,...], n_valid 16+1
        tbl = jnp.asarray([0, 2, 3, 4, 5, 6, 7, 7], jnp.int32)
        # new token -> logical slot 16 (block 2 of the shrunk table) =
        # physical block 3, offset 0
        lg, *_ = model.decode_fn(
            CFG, toks[start], jnp.int32(start), kc, vc, tbl,
            jnp.int32(3 * b), prefix_mask(nb, b, 2 * b + 1), *flat,
        )
        # Reference: jnp path with a hand-built cache of retained tokens only
        keep = list(range(0, 8)) + list(range(16, 24))
        kc2 = np.zeros_like(np.asarray(kc))
        vc2 = np.zeros_like(np.asarray(vc))
        kn, vn = np.asarray(k), np.asarray(v)
        for i, t in enumerate(keep):
            kc2[:, :, i // b, i % b] = kn[:, :, t]
            vc2[:, :, i // b, i % b] = vn[:, :, t]
        lg2, *_ = model.decode_fn(
            CFG, toks[start], jnp.int32(start),
            jnp.asarray(kc2), jnp.asarray(vc2),
            jnp.arange(nb, dtype=jnp.int32),
            jnp.int32(2 * b), prefix_mask(nb, b, 2 * b + 1), *flat,
        )
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lg2),
                                   rtol=1e-4, atol=1e-5)


class TestWeights:
    def test_roundtrip(self, weights):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "w.bin")
            model.save_weights(path, weights, CFG.weight_names())
            back = model.load_weights(path)
            assert set(back) == set(CFG.weight_names())
            for n in CFG.weight_names():
                np.testing.assert_array_equal(back[n], weights[n])

    def test_config_param_counts(self):
        for cfg in configs.MODELS.values():
            total = sum(int(np.prod(s)) for s in cfg.weight_shapes())
            assert total == cfg.n_params()
            assert len(cfg.weight_names()) == len(cfg.weight_shapes())

    def test_all_models_trace(self):
        """Every model config must produce valid prefill outputs."""
        for cfg in configs.MODELS.values():
            w = model.flatten_weights(cfg, model.init_weights(cfg))
            toks = jnp.zeros((16,), jnp.int32)
            lg, k, v, sc = model.prefill_fn(cfg, toks, jnp.int32(16), *w)
            assert lg.shape == (cfg.vocab_size,)
            assert np.isfinite(np.asarray(lg)).all()
