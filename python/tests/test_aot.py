"""AOT pipeline: HLO-text emission, manifest integrity, artifact matrix."""

import json
import os
import re
import tempfile

import pytest

from compile import aot, configs


def _entry_param_count(text: str) -> int:
    """Number of parameters of the entry computation (sub-computations also
    contain parameter() lines, so count the distinct indices on the maximal
    computation — the entry has the most)."""
    return 1 + max(int(m) for m in re.findall(r"parameter\((\d+)\)", text))


class TestLowering:
    def test_prefill_hlo_text(self):
        lowered = aot.lower_prefill(configs.SIM_1B, 16)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # Weights-as-parameters ABI: 2 runtime inputs + 21 weights
        assert _entry_param_count(text) == 2 + len(configs.SIM_1B.weight_names())

    def test_decode_hlo_text(self):
        lowered = aot.lower_decode(configs.SIM_1B, 8, 16)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert _entry_param_count(text) == 7 + len(configs.SIM_1B.weight_names())

    def test_jnp_ref_path_lowers(self):
        text = aot.to_hlo_text(
            aot.lower_decode(configs.SIM_1B, 4, 16, use_pallas=False)
        )
        assert text.startswith("HloModule")

    def test_decode_batch_hlo_text(self):
        lowered = aot.lower_decode_batch(configs.SIM_1B, 4, 16, batch=2)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # same runtime ABI as single decode, batch-stacked
        assert _entry_param_count(text) == 7 + len(configs.SIM_1B.weight_names())


class TestArtifactMatrix:
    def test_matrix_covers_paper_settings(self):
        specs = configs.artifact_matrix()
        names = {s.artifact_name for s in specs}
        assert len(names) == len(specs), "artifact names must be unique"
        # page 16 default (paper §5.1) for every model and decode bucket
        for m in configs.MODELS:
            for c in configs.DECODE_BUCKETS:
                assert f"decode_{m}_c{c}_b16" in names
            # fig-4 ablation page sizes
            for ps in configs.ABLATION_PAGE_SIZES:
                assert f"decode_{m}_c512_b{ps}" in names
            # batched decode lanes for the serving scheduler
            for c in configs.DECODE_BATCH_BUCKETS:
                lanes = configs.DECODE_BATCH_LANES
                assert f"decodeb{lanes}_{m}_c{c}_b16" in names

    def test_block_math(self):
        for s in configs.artifact_matrix():
            if s.kind in ("decode", "decode_batch"):
                assert s.n_blocks * s.page_size == s.seq_bucket

    def test_signatures_match_configs(self):
        for spec in configs.artifact_matrix(["sim-1b"]):
            cfg = configs.MODELS[spec.model]
            sig = aot.graph_signature(spec, cfg)
            if spec.kind == "decode":
                cache = sig["inputs"][2]["shape"]
                assert cache == [cfg.n_layers, cfg.n_kv_heads,
                                 spec.n_blocks, spec.page_size, cfg.d_head]
            if spec.kind == "decode_batch":
                cache = sig["inputs"][2]["shape"]
                assert cache == [spec.batch, cfg.n_layers, cfg.n_kv_heads,
                                 spec.n_blocks, spec.page_size, cfg.d_head]


class TestBuild:
    def test_build_single_model_subset(self):
        """End-to-end aot build for one model (full matrix covered by
        `make artifacts`; keep the test fast)."""
        with tempfile.TemporaryDirectory() as d:
            manifest = aot.build(d, models=["sim-1b"], verbose=False)
            assert os.path.exists(os.path.join(d, "manifest.json"))
            assert os.path.exists(os.path.join(d, "sim-1b.weights.bin"))
            with open(os.path.join(d, "manifest.json")) as f:
                on_disk = json.load(f)
            assert on_disk["models"]["sim-1b"]["n_params"] == \
                configs.SIM_1B.n_params()
            for g in manifest["graphs"]:
                path = os.path.join(d, g["path"])
                assert os.path.getsize(path) > 1000
                with open(path) as fh:
                    assert fh.read(9) == "HloModule"
