"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Includes hypothesis sweeps over shapes/dtypes/valid-lengths per the repo
test policy: the kernels must match ref.py to float32 tolerance for any
head-count/page-size/sequence combination the artifact matrix can produce.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    paged_attention, prefill_attention, token_scores, ref,
)


def prefix_mask(nb, b, n):
    """Structured prefix-validity mask: first n logical slots live."""
    return (np.arange(nb * b) < n).astype(np.float32).reshape(nb, b)

RTOL, ATOL = 1e-5, 1e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# prefill_attention
# ---------------------------------------------------------------------------

class TestPrefillAttention:
    @pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (8, 2), (2, 1)])
    @pytest.mark.parametrize("p", [8, 64])
    def test_matches_ref(self, hq, hkv, p):
        rng = np.random.default_rng(0)
        q, k, v = _rand(rng, hq, p, 16), _rand(rng, hkv, p, 16), _rand(rng, hkv, p, 16)
        n = p - 3
        got = prefill_attention(q, k, v, n)
        want = ref.causal_attention_ref(q, k, v, n)
        np.testing.assert_allclose(got[:, :n], want[:, :n], rtol=RTOL, atol=ATOL)

    def test_full_length(self):
        rng = np.random.default_rng(1)
        q, k, v = _rand(rng, 4, 32, 16), _rand(rng, 2, 32, 16), _rand(rng, 2, 32, 16)
        got = prefill_attention(q, k, v, 32)
        want = ref.causal_attention_ref(q, k, v, 32)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_first_row_attends_only_self(self):
        """Causality: row 0's output must equal v[0] expanded over groups."""
        rng = np.random.default_rng(2)
        q, k, v = _rand(rng, 4, 16, 8), _rand(rng, 2, 16, 8), _rand(rng, 2, 16, 8)
        got = prefill_attention(q, k, v, 16)
        want = ref.repeat_kv(v, 2)[:, 0]
        np.testing.assert_allclose(got[:, 0], want, rtol=RTOL, atol=ATOL)

    def test_padding_does_not_leak(self):
        """Changing K/V beyond `length` must not change valid outputs."""
        rng = np.random.default_rng(3)
        q, k, v = _rand(rng, 2, 32, 8), _rand(rng, 1, 32, 8), _rand(rng, 1, 32, 8)
        n = 20
        base = prefill_attention(q, k, v, n)
        k2 = k.at[:, n:].set(99.0)
        v2 = v.at[:, n:].set(-99.0)
        pert = prefill_attention(q, k2, v2, n)
        np.testing.assert_allclose(base[:, :n], pert[:, :n], rtol=RTOL, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(
        hkv=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        p=st.sampled_from([4, 8, 16, 48, 64]),
        dh=st.sampled_from([4, 8, 16, 32]),
        frac=st.floats(0.1, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, hkv, group, p, dh, frac, seed):
        rng = np.random.default_rng(seed)
        hq = hkv * group
        n = max(1, int(p * frac))
        q, k, v = _rand(rng, hq, p, dh), _rand(rng, hkv, p, dh), _rand(rng, hkv, p, dh)
        got = prefill_attention(q, k, v, n)
        want = ref.causal_attention_ref(q, k, v, n)
        np.testing.assert_allclose(got[:, :n], want[:, :n], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

class TestPagedAttention:
    @pytest.mark.parametrize("nb,b", [(4, 8), (8, 16), (2, 32), (16, 8)])
    def test_matches_ref(self, nb, b):
        rng = np.random.default_rng(0)
        hq, hkv, dh = 4, 2, 16
        q = _rand(rng, hq, dh)
        kc, vc = _rand(rng, hkv, nb, b, dh), _rand(rng, hkv, nb, b, dh)
        tbl = jnp.asarray(rng.permutation(nb), jnp.int32)
        m = jnp.asarray(prefix_mask(nb, b, nb * b - 5))
        got = paged_attention(q, kc, vc, tbl, m)
        want = ref.paged_attention_ref(q, kc, vc, tbl, m)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_block_table_permutation_invariance(self):
        """Attention over a full cache is a set operation: permuting both the
        physical blocks and the table must not change the output."""
        rng = np.random.default_rng(4)
        hq, hkv, nb, b, dh = 4, 2, 4, 8, 16
        q = _rand(rng, hq, dh)
        kc, vc = _rand(rng, hkv, nb, b, dh), _rand(rng, hkv, nb, b, dh)
        ident = jnp.arange(nb, dtype=jnp.int32)
        full = jnp.asarray(prefix_mask(nb, b, nb * b))
        base = paged_attention(q, kc, vc, ident, full)
        perm = np.asarray([2, 0, 3, 1])
        # physical blocks shuffled; table now maps logical i -> where block i went
        kc2 = jnp.asarray(np.asarray(kc)[:, perm])
        vc2 = jnp.asarray(np.asarray(vc)[:, perm])
        inv = np.argsort(perm).astype(np.int32)
        got = paged_attention(q, kc2, vc2, jnp.asarray(inv), full)
        np.testing.assert_allclose(got, base, rtol=RTOL, atol=ATOL)

    def test_invalid_slots_masked(self):
        """Garbage beyond n_valid (incl. stale evicted blocks) is invisible."""
        rng = np.random.default_rng(5)
        hq, hkv, nb, b, dh = 2, 1, 4, 8, 8
        q = _rand(rng, hq, dh)
        kc, vc = _rand(rng, hkv, nb, b, dh), _rand(rng, hkv, nb, b, dh)
        tbl = jnp.arange(nb, dtype=jnp.int32)
        m = jnp.asarray(prefix_mask(nb, b, 2 * b + 3))
        base = paged_attention(q, kc, vc, tbl, m)
        kc2 = kc.at[:, 3].set(1e4)  # stale physical block
        vc2 = vc.at[:, 3].set(-1e4)
        got = paged_attention(q, kc2, vc2, tbl, m)
        np.testing.assert_allclose(got, base, rtol=RTOL, atol=ATOL)

    def test_single_valid_token(self):
        rng = np.random.default_rng(6)
        hq, hkv, nb, b, dh = 2, 2, 2, 4, 8
        q = _rand(rng, hq, dh)
        kc, vc = _rand(rng, hkv, nb, b, dh), _rand(rng, hkv, nb, b, dh)
        tbl = jnp.arange(nb, dtype=jnp.int32)
        got = paged_attention(q, kc, vc, tbl, jnp.asarray(prefix_mask(nb, b, 1)))
        np.testing.assert_allclose(got, vc[:, 0, 0], rtol=RTOL, atol=ATOL)

    @settings(max_examples=25, deadline=None)
    @given(
        hkv=st.sampled_from([1, 2]),
        group=st.sampled_from([1, 2, 4]),
        nb=st.sampled_from([2, 4, 8]),
        b=st.sampled_from([4, 8, 16, 32]),
        dh=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, hkv, group, nb, b, dh, seed):
        rng = np.random.default_rng(seed)
        hq = hkv * group
        q = _rand(rng, hq, dh)
        kc, vc = _rand(rng, hkv, nb, b, dh), _rand(rng, hkv, nb, b, dh)
        tbl = jnp.asarray(rng.permutation(nb), jnp.int32)
        # random hole-punched mask (unstructured eviction shape)
        m = (rng.random((nb, b)) < 0.7).astype(np.float32)
        m[0, 0] = 1.0  # at least one live token
        m = jnp.asarray(m)
        got = paged_attention(q, kc, vc, tbl, m)
        want = ref.paged_attention_ref(q, kc, vc, tbl, m)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# token_scores
# ---------------------------------------------------------------------------

class TestTokenScores:
    @pytest.mark.parametrize("hkv,p,dh", [(2, 16, 8), (1, 64, 16), (4, 32, 32)])
    def test_matches_ref(self, hkv, p, dh):
        rng = np.random.default_rng(0)
        k, v = _rand(rng, hkv, p, dh), _rand(rng, hkv, p, dh)
        n = p - 2
        got = token_scores(k, v, n)
        want = ref.token_scores_ref(k, v, n)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_invalid_positions_zeroed(self):
        rng = np.random.default_rng(1)
        k, v = _rand(rng, 2, 16, 8), _rand(rng, 2, 16, 8)
        got = np.asarray(token_scores(k, v, 10))
        assert (got[:, 10:] == 0).all()

    def test_vk_ratio_semantics(self):
        """Doubling V doubles channel 0 and leaves channels 1-2 unchanged."""
        rng = np.random.default_rng(2)
        k, v = _rand(rng, 2, 16, 8), _rand(rng, 2, 16, 8)
        a = np.asarray(token_scores(k, v, 16))
        b = np.asarray(token_scores(k, 2.0 * v, 16))
        np.testing.assert_allclose(b[0], 2.0 * a[0], rtol=1e-4)
        np.testing.assert_allclose(b[1:], a[1:], rtol=1e-5)

    def test_keydiff_identical_keys_cos_one(self):
        """All-identical keys are maximally redundant: cosine == 1."""
        k = jnp.ones((2, 8, 4), jnp.float32)
        rng = np.random.default_rng(3)
        v = _rand(rng, 2, 8, 4)
        got = np.asarray(token_scores(k, v, 8))
        np.testing.assert_allclose(got[2], np.ones(8), rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        hkv=st.sampled_from([1, 2, 4]),
        p=st.sampled_from([4, 16, 64]),
        dh=st.sampled_from([4, 16, 32]),
        frac=st.floats(0.2, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, hkv, p, dh, frac, seed):
        rng = np.random.default_rng(seed)
        k, v = _rand(rng, hkv, p, dh), _rand(rng, hkv, p, dh)
        n = max(1, int(p * frac))
        got = token_scores(k, v, n)
        want = ref.token_scores_ref(k, v, n)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestDecodeTokenScores:
    def test_consistent_with_prefill_scores(self):
        """The decode-step score of token i must match the prefill kernel's
        score for the same token (same K/V contents)."""
        rng = np.random.default_rng(7)
        hkv, p, dh, b = 2, 16, 8, 4
        k, v = _rand(rng, hkv, p, dh), _rand(rng, hkv, p, dh)
        full = ref.token_scores_ref(k, v, p)
        nb = p // b
        kc = np.asarray(k).reshape(hkv, nb, b, dh)
        tbl = jnp.arange(nb, dtype=jnp.int32)
        got = ref.decode_token_scores_ref(
            k[:, p - 1], v[:, p - 1], jnp.asarray(kc), tbl,
            jnp.asarray(prefix_mask(nb, b, p)),
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(full)[:, p - 1],
                                   rtol=1e-4, atol=1e-5)
