"""Layer-1 Pallas kernels (interpret=True) + pure-jnp oracles."""

from . import ref
from .paged_attention import paged_attention
from .prefill_attention import prefill_attention
from .token_scores import token_scores

__all__ = ["ref", "paged_attention", "prefill_attention", "token_scores"]
