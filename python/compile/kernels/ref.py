"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: the pytest suite asserts the Pallas
kernels (interpret=True) match these to float32 tolerance, and the L2 model
can be built against either implementation (``use_pallas=False``) for
ablation and debugging.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x, n_rep: int):
    """[Hkv, S, dh] -> [Hkv * n_rep, S, dh] (GQA head expansion)."""
    if n_rep == 1:
        return x
    hkv, s, dh = x.shape
    return jnp.broadcast_to(x[:, None], (hkv, n_rep, s, dh)).reshape(
        hkv * n_rep, s, dh
    )


def causal_attention_ref(q, k, v, length):
    """Causal self-attention over a (padded) prompt.

    q: [Hq, P, dh]; k, v: [Hkv, P, dh]; length: scalar i32 — positions
    >= length are padding and masked out of the key axis.
    Returns [Hq, P, dh]. Rows >= length are garbage (never read).
    """
    hq, p, dh = q.shape
    hkv = k.shape[0]
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(dh))
    rows = jnp.arange(p)[:, None]
    cols = jnp.arange(p)[None, :]
    mask = (cols <= rows) & (cols < length)
    scores = jnp.where(mask[None], scores, NEG_INF)
    attn = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    attn = attn / attn.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", attn, v)


def paged_attention_ref(q, k_cache, v_cache, block_table, valid_mask):
    """Decode-time attention over a paged KV cache.

    q: [Hq, dh] — the single new token's query (already RoPE-rotated).
    k_cache, v_cache: [Hkv, NB, B, dh] — physical block pool slice for this
        sequence (physical slot order).
    block_table: [NB] i32 — logical->physical block mapping; entries past the
        live block count may be arbitrary (masked via valid_mask).
    valid_mask: f32[NB, B] in LOGICAL (table) order — 1.0 where the slot
        holds a live token (including the token being decoded), 0.0 for
        padding, stale slots, or tokens hole-punched by *unstructured*
        eviction baselines (InverseKeyNorm / KeyDiff / StreamingLLM decode).
    Returns [Hq, dh].
    """
    hq, dh = q.shape
    hkv, nb, b, _ = k_cache.shape
    # Gather blocks into logical order, then flatten the token axis.
    k = jnp.take(k_cache, block_table, axis=1).reshape(hkv, nb * b, dh)
    v = jnp.take(v_cache, block_table, axis=1).reshape(hkv, nb * b, dh)
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    scores = jnp.einsum("hd,hkd->hk", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = valid_mask.reshape(nb * b) > 0.5
    scores = jnp.where(mask[None], scores, NEG_INF)
    attn = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    attn = attn / attn.sum(axis=-1, keepdims=True)
    return jnp.einsum("hk,hkd->hd", attn, v)


def token_scores_ref(k, v, length, eps: float = 1e-8):
    """Three attention-free importance channels per token (paper Alg. 1 plus
    the two baseline metrics).

    k, v: [Hkv, P, dh]; length: scalar i32.
    Returns [3, P]:
      [0] PagedEviction proxy  S_i = mean_h ||V_hi|| / ||K_hi||  (higher = keep)
      [1] key L2 norm          mean_h ||K_hi||                   (raw; the
          InverseKeyNorm policy treats LOW norm as important)
      [2] KeyDiff cosine       mean_h cos(K_hi, anchor_h)        (raw; KeyDiff
          treats HIGH similarity as redundant)
    Entries at positions >= length are zeroed.
    """
    hkv, p, dh = k.shape
    kn = jnp.linalg.norm(k, axis=-1)  # [Hkv, P]
    vn = jnp.linalg.norm(v, axis=-1)
    valid = (jnp.arange(p) < length).astype(k.dtype)  # [P]
    vk_ratio = (vn / (kn + eps)).mean(axis=0)
    key_l2 = kn.mean(axis=0)
    # KeyDiff anchor: per-head mean of the *valid* keys.
    denom = jnp.maximum(valid.sum(), 1.0)
    anchor = (k * valid[None, :, None]).sum(axis=1) / denom  # [Hkv, dh]
    an = jnp.linalg.norm(anchor, axis=-1, keepdims=True)  # [Hkv, 1]
    cos = jnp.einsum("hpd,hd->hp", k, anchor / (an + eps)) / (kn + eps)
    keydiff = cos.mean(axis=0)
    return jnp.stack([vk_ratio, key_l2, keydiff]) * valid[None]


def decode_token_scores_ref(k_new, v_new, k_cache, block_table, valid_mask,
                            eps: float = 1e-8):
    """Score channels for the single token produced by a decode step.

    k_new, v_new: [Hkv, dh]; k_cache: [Hkv, NB, B, dh] (already containing
    the new key); valid_mask: f32[NB, B] in logical order, including the new
    token. Returns [3] — same channels as token_scores_ref.
    """
    kn = jnp.linalg.norm(k_new, axis=-1)  # [Hkv]
    vn = jnp.linalg.norm(v_new, axis=-1)
    vk_ratio = (vn / (kn + eps)).mean()
    key_l2 = kn.mean()
    hkv, nb, b, dh = k_cache.shape
    k = jnp.take(k_cache, block_table, axis=1).reshape(hkv, nb * b, dh)
    valid = valid_mask.reshape(nb * b).astype(k.dtype)
    denom = jnp.maximum(valid.sum(), 1.0)
    anchor = (k * valid[None, :, None]).sum(axis=1) / denom  # [Hkv, dh]
    an = jnp.linalg.norm(anchor, axis=-1)
    cos = jnp.einsum("hd,hd->h", k_new, anchor) / ((kn * an) + eps)
    return jnp.stack([vk_ratio, key_l2, cos.mean()])
