"""Pallas kernel: decode-time paged attention over a block table.

This is the compute hot-spot of the serving system: every decode step, every
layer, reads the entire live KV cache through the block table. The TPU
adaptation of vLLM's CUDA PagedAttention (DESIGN.md §3):

  * the block table is the HBM->VMEM gather schedule — each logical page is
    fetched from its physical slot (`jnp.take` along the page axis stands in
    for the per-page DMA a Mosaic kernel would issue);
  * pages are the VMEM tiles: one KV page = one [B, dh] tile, so the VMEM
    working set is O(NB·B·dh) per KV head and independent of eviction state;
  * the softmax runs entirely in-register/VMEM — attention weights are never
    written back, which is precisely why PagedEviction's importance proxy
    must be attention-free.

Because the grid is over KV heads and the flattened token axis is NB*B, the
lowered HLO's gather/matmul trip counts scale with the context bucket — this
is the mechanism that turns block eviction into real decode-step speedup
under AOT shape bucketing.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _kernel(q_ref, k_ref, v_ref, tbl_ref, vm_ref, o_ref, *, d_head: int):
    # q_ref: [G, dh]; k_ref, v_ref: [1, NB, B, dh]; tbl_ref: [NB] i32;
    # vm_ref: [NB, B] f32 validity in logical order.
    q = q_ref[...]
    tbl = tbl_ref[...]
    _, nb, b, dh = k_ref.shape
    # Gather pages into logical order (the block-table indirection).
    k = jnp.take(k_ref[0], tbl, axis=0).reshape(nb * b, dh)
    v = jnp.take(v_ref[0], tbl, axis=0).reshape(nb * b, dh)
    scores = jnp.einsum(
        "gd,kd->gk", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(d_head))
    mask = vm_ref[...].reshape(1, nb * b) > 0.5
    scores = jnp.where(mask, scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / e.sum(axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum(
        "gk,kd->gd", attn, v, preferred_element_type=jnp.float32
    )


def paged_attention(q, k_cache, v_cache, block_table, valid_mask):
    """Single-token attention against a paged KV cache.

    q: [Hq, dh] (RoPE already applied); k_cache, v_cache: [Hkv, NB, B, dh]
    in PHYSICAL slot order; block_table: [NB] i32 logical->physical;
    valid_mask: f32[NB, B] in LOGICAL order — 1.0 for live tokens (including
    the current one), 0.0 for padding/stale/hole-punched slots.
    Returns [Hq, dh].
    """
    hq, dh = q.shape
    hkv, nb, b, _ = k_cache.shape
    assert hq % hkv == 0
    g = hq // hkv
    block_table = jnp.asarray(block_table, jnp.int32)
    valid_mask = jnp.asarray(valid_mask, jnp.float32)
    kernel = functools.partial(_kernel, d_head=dh)
    return pl.pallas_call(
        kernel,
        grid=(hkv,),
        in_specs=[
            pl.BlockSpec((g, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, nb, b, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, nb, b, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((nb,), lambda i: (0,)),
            pl.BlockSpec((nb, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((g, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, dh), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, block_table, valid_mask)
