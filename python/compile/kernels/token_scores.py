"""Pallas kernel: attention-free token-importance scores (paper Alg. 1).

Computes, in a single fused pass over the K/V tiles that the prefill kernel
already touched (no extra HBM traffic — DESIGN.md §3):

  channel 0  S_i = mean_h ||V_hi|| / ||K_hi||   — PagedEviction's proxy
  channel 1  mean_h ||K_hi||                    — Inverse Key L2-Norm input
  channel 2  mean_h cos(K_hi, mean-key anchor)  — KeyDiff input

The three channels cost one extra reduction each over data already in VMEM;
this is the paper's point that the proxy is computable "on-the-fly without
modifying the attention kernel or maintaining additional memory".
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8


def _kernel(k_ref, v_ref, len_ref, o_ref, *, n_kv_heads: int):
    # k_ref, v_ref: [Hkv, P, dh]; len_ref: [1] i32; o_ref: [3, P].
    k = k_ref[...]
    v = v_ref[...]
    length = len_ref[0]
    hkv, p, dh = k.shape
    kn = jnp.sqrt(jnp.sum(k * k, axis=-1))  # [Hkv, P]
    vn = jnp.sqrt(jnp.sum(v * v, axis=-1))
    valid = (jax.lax.broadcasted_iota(jnp.int32, (p,), 0) < length).astype(
        k.dtype
    )
    vk_ratio = (vn / (kn + EPS)).mean(axis=0)
    key_l2 = kn.mean(axis=0)
    denom = jnp.maximum(valid.sum(), 1.0)
    anchor = (k * valid[None, :, None]).sum(axis=1) / denom  # [Hkv, dh]
    an = jnp.sqrt(jnp.sum(anchor * anchor, axis=-1, keepdims=True))
    cos = jnp.einsum(
        "hpd,hd->hp", k, anchor / (an + EPS),
        preferred_element_type=jnp.float32,
    ) / (kn + EPS)
    keydiff = cos.mean(axis=0)
    o_ref[...] = jnp.stack([vk_ratio, key_l2, keydiff]) * valid[None]


def token_scores(k, v, length):
    """k, v: [Hkv, P, dh]; length: scalar i32. Returns [3, P] (see module
    docstring); positions >= length are zeroed."""
    hkv, p, dh = k.shape
    length = jnp.asarray(length, jnp.int32).reshape(1)
    kernel = functools.partial(_kernel, n_kv_heads=hkv)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((hkv, p, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((hkv, p, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((3, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((3, p), jnp.float32),
        interpret=True,
    )(k, v, length)
