"""Pallas kernel: causal prompt attention (prefill phase).

Grid layout (the TPU adaptation of the paper's CUDA prefill path, DESIGN.md
§3): one grid step per KV head group. For each group the query tile
[G, P, dh] and its KV tile [P, dh] are VMEM-resident; scores and the softmax
are computed entirely in-tile, so there is no extra HBM traffic for
attention weights — the property PagedEviction relies on (attention scores
are never materialized to memory, so eviction must be attention-free).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated in DESIGN.md §Perf instead.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, d_head: int):
    # q_ref: [G, P, dh]; k_ref, v_ref: [1, P, dh]; len_ref: [1] i32.
    q = q_ref[...]
    k = k_ref[0]
    v = v_ref[0]
    length = len_ref[0]
    g, p, dh = q.shape
    scores = jnp.einsum(
        "gqd,kd->gqk", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(d_head))
    rows = jax.lax.broadcasted_iota(jnp.int32, (p, p), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (p, p), 1)
    mask = (cols <= rows) & (cols < length)
    scores = jnp.where(mask[None], scores, NEG_INF)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / e.sum(axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum(
        "gqk,kd->gqd", attn, v, preferred_element_type=jnp.float32
    )


def prefill_attention(q, k, v, length):
    """Causal attention over a padded prompt.

    q: [Hq, P, dh]; k, v: [Hkv, P, dh]; length: scalar i32 (valid prefix).
    Returns [Hq, P, dh] (rows >= length are garbage, never read).
    """
    hq, p, dh = q.shape
    hkv = k.shape[0]
    assert hq % hkv == 0
    g = hq // hkv
    length = jnp.asarray(length, jnp.int32).reshape(1)
    kernel = functools.partial(_kernel, d_head=dh)
    return pl.pallas_call(
        kernel,
        grid=(hkv,),
        in_specs=[
            pl.BlockSpec((g, p, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((g, p, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, p, dh), jnp.float32),
        interpret=True,
    )(q, k, v, length)
