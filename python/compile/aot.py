"""AOT lowering: JAX graphs -> StableHLO -> XLA HLO TEXT artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids so text round-trips
cleanly. See /opt/xla-example/README.md.

Outputs under artifacts/:
  <name>.hlo.txt          one per GraphSpec in configs.artifact_matrix()
  <model>.weights.bin     PEW1 container (trained weights win if present)
  manifest.json           everything the Rust runtime needs: model configs,
                          weight ABI, graph shapes and input signatures.

Usage: python -m compile.aot --out ../artifacts [--models sim-1b,...]
       [--jnp-ref] (lower the pure-jnp path instead of Pallas — ablation)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _weight_specs(cfg):
    return [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in cfg.weight_shapes()
    ]


def lower_prefill(cfg: configs.ModelConfig, p: int, use_pallas: bool = True):
    fn = functools.partial(model.prefill_fn, cfg, use_pallas=use_pallas)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    toks = jax.ShapeDtypeStruct((p,), jnp.int32)
    return jax.jit(fn).lower(toks, i32, *_weight_specs(cfg))


def lower_decode(cfg: configs.ModelConfig, nb: int, page: int,
                 use_pallas: bool = True):
    fn = functools.partial(model.decode_fn, cfg, use_pallas=use_pallas)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_kv_heads, nb, page, cfg.d_head), jnp.float32
    )
    tbl = jax.ShapeDtypeStruct((nb,), jnp.int32)
    vmask = jax.ShapeDtypeStruct((nb, page), jnp.float32)
    return jax.jit(fn).lower(i32, i32, cache, cache, tbl, i32, vmask,
                             *_weight_specs(cfg))


def lower_decode_batch(cfg: configs.ModelConfig, nb: int, page: int,
                       batch: int, use_pallas: bool = True):
    """Batched decode: vmap the single-sequence decode over a leading batch
    axis on every runtime input (token, pos, caches, table, write slot,
    validity mask), broadcasting the weights. One dispatch steps `batch`
    independent sequences — the serving scheduler's whole running set."""
    fn = functools.partial(model.decode_fn, cfg, use_pallas=use_pallas)
    n_w = len(cfg.weight_shapes())
    bfn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, 0) + (None,) * n_w)
    i32v = jax.ShapeDtypeStruct((batch,), jnp.int32)
    cache = jax.ShapeDtypeStruct(
        (batch, cfg.n_layers, cfg.n_kv_heads, nb, page, cfg.d_head),
        jnp.float32,
    )
    tbl = jax.ShapeDtypeStruct((batch, nb), jnp.int32)
    vmask = jax.ShapeDtypeStruct((batch, nb, page), jnp.float32)
    return jax.jit(bfn).lower(i32v, i32v, cache, cache, tbl, i32v, vmask,
                              *_weight_specs(cfg))


def graph_signature(spec: configs.GraphSpec, cfg: configs.ModelConfig):
    """Runtime-facing input/output signature (before the *weights tail)."""
    dh, l, hkv = cfg.d_head, cfg.n_layers, cfg.n_kv_heads
    if spec.kind == "prefill":
        p = spec.seq_bucket
        return {
            "inputs": [
                {"name": "tokens", "dtype": "i32", "shape": [p]},
                {"name": "length", "dtype": "i32", "shape": []},
            ],
            "outputs": [
                {"name": "logits", "dtype": "f32", "shape": [cfg.vocab_size]},
                {"name": "k", "dtype": "f32", "shape": [l, hkv, p, dh]},
                {"name": "v", "dtype": "f32", "shape": [l, hkv, p, dh]},
                {"name": "scores", "dtype": "f32", "shape": [3, l, p]},
            ],
        }
    nb, b = spec.n_blocks, spec.page_size
    if spec.kind == "decode_batch":
        s = spec.batch
        cache = [s, l, hkv, nb, b, dh]
        return {
            "inputs": [
                {"name": "tokens", "dtype": "i32", "shape": [s]},
                {"name": "pos", "dtype": "i32", "shape": [s]},
                {"name": "k_cache", "dtype": "f32", "shape": cache},
                {"name": "v_cache", "dtype": "f32", "shape": cache},
                {"name": "block_table", "dtype": "i32", "shape": [s, nb]},
                {"name": "write_slot", "dtype": "i32", "shape": [s]},
                {"name": "valid_mask", "dtype": "f32", "shape": [s, nb, b]},
            ],
            "outputs": [
                {"name": "logits", "dtype": "f32", "shape": [s, cfg.vocab_size]},
                {"name": "k_cache", "dtype": "f32", "shape": cache},
                {"name": "v_cache", "dtype": "f32", "shape": cache},
                {"name": "scores", "dtype": "f32", "shape": [s, 3, l]},
            ],
        }
    cache = [l, hkv, nb, b, dh]
    return {
        "inputs": [
            {"name": "token", "dtype": "i32", "shape": []},
            {"name": "pos", "dtype": "i32", "shape": []},
            {"name": "k_cache", "dtype": "f32", "shape": cache},
            {"name": "v_cache", "dtype": "f32", "shape": cache},
            {"name": "block_table", "dtype": "i32", "shape": [nb]},
            {"name": "write_slot", "dtype": "i32", "shape": []},
            {"name": "valid_mask", "dtype": "f32", "shape": [nb, b]},
        ],
        "outputs": [
            {"name": "logits", "dtype": "f32", "shape": [cfg.vocab_size]},
            {"name": "k_cache", "dtype": "f32", "shape": cache},
            {"name": "v_cache", "dtype": "f32", "shape": cache},
            {"name": "scores", "dtype": "f32", "shape": [3, l]},
        ],
    }


def build(out_dir: str, models=None, use_pallas: bool = True,
          verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    names = models or list(configs.MODELS)
    manifest = {
        "format": 1,
        "kernel_impl": "pallas_interpret" if use_pallas else "jnp_ref",
        "models": {},
        "graphs": [],
    }
    for mname in names:
        cfg = configs.MODELS[mname]
        wpath = os.path.join(out_dir, f"{mname}.weights.bin")
        trained = os.path.join(out_dir, f"{mname}.trained.bin")
        if os.path.exists(trained):
            weights = model.load_weights(trained)
            src = "trained"
        elif os.path.exists(wpath):
            weights = model.load_weights(wpath)
            src = "cached"
        else:
            weights = model.init_weights(cfg)
            src = "random-init(seed=42)"
        model.save_weights(wpath, weights, cfg.weight_names())
        manifest["models"][mname] = {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
            "d_ff": cfg.d_ff, "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps, "n_params": cfg.n_params(),
            "weights": os.path.basename(wpath), "weights_src": src,
            "weight_names": cfg.weight_names(),
            "weight_shapes": [list(s) for s in cfg.weight_shapes()],
        }
        if verbose:
            print(f"[aot] {mname}: weights = {src} ({cfg.n_params()} params)")

    for spec in configs.artifact_matrix(names):
        cfg = configs.MODELS[spec.model]
        if spec.kind == "prefill":
            lowered = lower_prefill(cfg, spec.seq_bucket, use_pallas)
        elif spec.kind == "decode_batch":
            lowered = lower_decode_batch(cfg, spec.n_blocks, spec.page_size,
                                         spec.batch, use_pallas)
        else:
            lowered = lower_decode(cfg, spec.n_blocks, spec.page_size,
                                   use_pallas)
        text = to_hlo_text(lowered)
        fname = f"{spec.artifact_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": spec.artifact_name, "kind": spec.kind,
            "model": spec.model, "path": fname,
            "seq_bucket": spec.seq_bucket,
        }
        if spec.kind in ("decode", "decode_batch"):
            entry["page_size"] = spec.page_size
            entry["n_blocks"] = spec.n_blocks
        if spec.kind == "decode_batch":
            entry["batch"] = spec.batch
        entry.update(graph_signature(spec, cfg))
        manifest["graphs"].append(entry)
        if verbose:
            print(f"[aot] lowered {spec.artifact_name} "
                  f"({len(text) // 1024} KiB hlo text)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] wrote {len(manifest['graphs'])} graphs -> {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of models")
    ap.add_argument("--jnp-ref", action="store_true",
                    help="lower the pure-jnp reference path (ablation)")
    args = ap.parse_args()
    models = args.models.split(",") if args.models else None
    build(args.out, models, use_pallas=not args.jnp_ref)


if __name__ == "__main__":
    main()
