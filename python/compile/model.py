"""Layer-2 JAX model: Llama-style decoder (RMSNorm, RoPE, GQA, SwiGLU).

Two graphs per model, AOT-lowered by aot.py and executed from Rust via PJRT:

  prefill(tokens i32[P], length i32, *weights)
      -> (logits f32[V], k f32[L,Hkv,P,dh], v f32[L,Hkv,P,dh],
          scores f32[3,L,P])

  decode(token i32[], pos i32[], k_cache f32[L,Hkv,NB,B,dh],
         v_cache f32[L,Hkv,NB,B,dh], block_table i32[NB],
         write_slot i32[], valid_mask f32[NB,B], *weights)
      -> (logits f32[V], k_cache', v_cache', scores f32[3,L])

Weights are passed as parameters (NOT baked as constants) so the HLO text
stays small; Rust loads them once from <model>.weights.bin and keeps them
device-resident. The flattened order is ModelConfig.weight_names() — that
list is the runtime ABI.

Conventions shared with the Rust coordinator (rust/src/runtime):
  * K is cached POST-RoPE, so eviction/gather never re-rotates keys and
    retained tokens keep their original positions (standard for
    eviction-style compression).
  * The block table maps logical page order -> physical slot; `valid_mask`
    marks live tokens in logical order (1.0/0.0) — structured policies keep
    it a full prefix, unstructured baselines hole-punch it; `write_slot` is
    a PHYSICAL flat index block*B + offset.
"""

import struct
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import paged_attention, prefill_attention, token_scores
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_weights(cfg: ModelConfig, seed: int = 42) -> Dict[str, np.ndarray]:
    """Deterministic scaled-normal init, keyed by the canonical weight order."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    for name, shape in zip(cfg.weight_names(), cfg.weight_shapes()):
        if name.endswith("norm"):
            w = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / np.sqrt(fan_in)
            w = rng.normal(0.0, std, size=shape).astype(np.float32)
        out[name] = w
    return out


_DTYPE_CODES = {0: np.float32, 1: np.int32}
_DTYPE_IDS = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def save_weights(path: str, weights: Dict[str, np.ndarray],
                 order: List[str]) -> None:
    """PEW1 container (DESIGN.md §7): magic, count, then per tensor
    (u16 name_len, name, u8 dtype, u8 rank, u32 dims[rank], raw LE data)."""
    with open(path, "wb") as f:
        f.write(b"PEW1")
        f.write(struct.pack("<I", len(order)))
        for name in order:
            w = np.ascontiguousarray(weights[name])
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_IDS[w.dtype], w.ndim))
            for d in w.shape:
                f.write(struct.pack("<I", d))
            f.write(w.tobytes())


def load_weights(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"PEW1", "bad magic"
    (count,) = struct.unpack_from("<I", data, 4)
    off = 8
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off:off + nlen].decode()
        off += nlen
        dtype_id, rank = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{rank}I", data, off)
        off += 4 * rank
        dt = np.dtype(_DTYPE_CODES[dtype_id])
        size = int(np.prod(dims)) * dt.itemsize
        out[name] = np.frombuffer(
            data, dt, count=int(np.prod(dims)), offset=off
        ).reshape(dims).copy()
        off += size
    return out


def flatten_weights(cfg: ModelConfig, weights: Dict[str, np.ndarray]):
    return [jnp.asarray(weights[n]) for n in cfg.weight_names()]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta: float):
    """x: [H, S, dh]; positions: [S] i32. Llama-style rotary embedding."""
    h, s, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None]  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _unpack_layers(cfg: ModelConfig, flat):
    """flat weights (ABI order) -> (emb, [per-layer dicts], out_norm, head)."""
    emb = flat[0]
    layers = []
    i = 1
    for _ in range(cfg.n_layers):
        names = ("attn_norm", "wq", "wk", "wv", "wo",
                 "mlp_norm", "w_gate", "w_up", "w_down")
        layers.append(dict(zip(names, flat[i:i + 9])))
        i += 9
    return emb, layers, flat[i], flat[i + 1]


def _attn_proj(cfg: ModelConfig, x, layer, positions):
    """Project + reshape + rope. x: [S, d]. Returns q:[Hq,S,dh],
    k,v:[Hkv,S,dh] (k post-RoPE, v raw)."""
    s = x.shape[0]
    q = (x @ layer["wq"]).reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (x @ layer["wk"]).reshape(s, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (x @ layer["wv"]).reshape(s, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp(x, layer):
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------


def prefill_fn(cfg: ModelConfig, tokens, length, *flat_weights,
               use_pallas: bool = True):
    """See module docstring. tokens: i32[P]; length: i32 scalar."""
    emb, layers, out_norm, head = _unpack_layers(cfg, list(flat_weights))
    p = tokens.shape[0]
    positions = jnp.arange(p, dtype=jnp.int32)
    h = emb[tokens]
    ks, vs, scores = [], [], []
    for layer in layers:
        x = rms_norm(h, layer["attn_norm"], cfg.norm_eps)
        q, k, v = _attn_proj(cfg, x, layer, positions)
        if use_pallas:
            attn = prefill_attention(q, k, v, length)
            sc = token_scores(k, v, length)
        else:
            attn = kref.causal_attention_ref(q, k, v, length)
            sc = kref.token_scores_ref(k, v, length)
        attn = attn.transpose(1, 0, 2).reshape(p, cfg.q_dim)
        h = h + attn @ layer["wo"]
        x = rms_norm(h, layer["mlp_norm"], cfg.norm_eps)
        h = h + _mlp(x, layer)
        ks.append(k)
        vs.append(v)
        scores.append(sc)
    h = rms_norm(h, out_norm, cfg.norm_eps)
    last = jax.lax.dynamic_index_in_dim(h, length - 1, axis=0, keepdims=False)
    logits = last @ head
    k_all = jnp.stack(ks)       # [L, Hkv, P, dh]
    v_all = jnp.stack(vs)
    sc_all = jnp.stack(scores).transpose(1, 0, 2)  # [3, L, P]
    return logits, k_all, v_all, sc_all


def decode_fn(cfg: ModelConfig, token, pos, k_cache, v_cache, block_table,
              write_slot, valid_mask, *flat_weights, use_pallas: bool = True):
    """One decode step against the paged cache. See module docstring.

    token, pos, write_slot: i32 scalars; k_cache/v_cache:
    [L, Hkv, NB, B, dh]; block_table: i32[NB]; valid_mask: f32[NB, B] in
    LOGICAL order, 1.0 for live tokens INCLUDING this one (unstructured
    baselines hole-punch individual slots to 0.0). write_slot is the
    physical flat slot where this token's K/V goes.
    """
    emb, layers, out_norm, head = _unpack_layers(cfg, list(flat_weights))
    l, hkv, nb, b, dh = k_cache.shape
    positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
    h = emb[jnp.reshape(token, (1,))]  # [1, d]
    new_k_caches, new_v_caches, scores = [], [], []
    for li, layer in enumerate(layers):
        x = rms_norm(h, layer["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = _attn_proj(cfg, x, layer, positions)
        # Scatter the new token's K/V into its physical slot.
        kc = k_cache[li].reshape(hkv, nb * b, dh)
        vc = v_cache[li].reshape(hkv, nb * b, dh)
        kc = jax.lax.dynamic_update_slice(kc, k_new, (0, write_slot, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_new, (0, write_slot, 0))
        kc4 = kc.reshape(hkv, nb, b, dh)
        vc4 = vc.reshape(hkv, nb, b, dh)
        if use_pallas:
            attn = paged_attention(q[:, 0], kc4, vc4, block_table, valid_mask)
        else:
            attn = kref.paged_attention_ref(
                q[:, 0], kc4, vc4, block_table, valid_mask
            )
        h = h + attn.reshape(1, cfg.q_dim) @ layer["wo"]
        x = rms_norm(h, layer["mlp_norm"], cfg.norm_eps)
        h = h + _mlp(x, layer)
        new_k_caches.append(kc4)
        new_v_caches.append(vc4)
        scores.append(
            kref.decode_token_scores_ref(
                k_new[:, 0], v_new[:, 0], kc4, block_table, valid_mask
            )
        )
    h = rms_norm(h, out_norm, cfg.norm_eps)
    logits = (h @ head)[0]
    sc = jnp.stack(scores, axis=1)  # [3, L]
    return logits, jnp.stack(new_k_caches), jnp.stack(new_v_caches), sc
