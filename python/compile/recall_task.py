"""Associative-recall task definition — shared between the trainer and the
Rust workload generator (rust/src/workload/recall.rs mirrors these exact
constants; change them together).

A sequence is a stream of (key, value) pairs under a per-sequence random
mapping, optionally ending in a query:

    k1 v1 k2 v2 k1 v1 ... Q kq  ->  model must emit v(kq)

Every later occurrence of a key is followed by the same value, so a
next-token LM that forms induction heads learns to copy the value from the
earlier occurrence — making long-context retention (and therefore the KV
eviction policy) directly measurable as recall accuracy.
"""

import numpy as np

PAD = 0
KEY_BASE = 1       # keys: 1..=N_KEYS
N_KEYS = 16
VAL_BASE = 32      # values: 32..=32+N_VALS-1
N_VALS = 16
QUERY = 64         # query marker
VOCAB_USED = 65    # tokens above this are unused (vocab is 256)


def sample_mapping(rng: np.random.Generator) -> np.ndarray:
    """Per-sequence key->value mapping (random with replacement)."""
    return rng.integers(0, N_VALS, size=N_KEYS) + VAL_BASE


def make_training_batch(rng: np.random.Generator, batch: int, seq: int):
    """LM training batch matching the eval format: a pair stream with
    interspersed [QUERY, k] probes whose next token must be the value bound
    to k earlier in the sequence.

    Returns (tokens [B,S] int32, loss_mask [B,S] float32): value positions
    after a repeated key get weight 2.0, first occurrences 1.0, values after
    a query probe 4.0 (the eval-critical pattern), everything else 0.
    """
    toks = np.zeros((batch, seq), np.int32)
    mask = np.zeros((batch, seq), np.float32)
    for b in range(batch):
        vmap = sample_mapping(rng)
        # curriculum: some sequences use few keys (dense repeats — easy for
        # the induction circuit to discover), others the full key set
        n_active = int(rng.choice([4, 8, N_KEYS]))
        active = rng.permutation(N_KEYS)[:n_active]
        seen = []
        i = 0
        while i + 2 < seq:
            if seen and i > seq // 8 and rng.random() < 0.25 and i + 3 < seq:
                # query probe on a previously bound key
                k = int(seen[rng.integers(0, len(seen))])
                toks[b, i] = QUERY
                toks[b, i + 1] = KEY_BASE + k
                toks[b, i + 2] = vmap[k]
                mask[b, i + 2] = 4.0
                i += 3
            else:
                k = int(active[rng.integers(0, n_active)])
                toks[b, i] = KEY_BASE + k
                toks[b, i + 1] = vmap[k]
                mask[b, i + 1] = 2.0 if k in seen else 0.2
                if k not in seen:
                    seen.append(k)
                i += 2
    return toks, mask


def make_eval_prompt(rng: np.random.Generator, prompt_len: int,
                     needle_frac: float = 0.25):
    """Needle-retrieval prompt: pair stream with the queried key planted at
    `needle_frac` of the way through, query at the end.

    Returns (tokens list[int], answer token int, needle_positions (k_pos,
    v_pos)). The prompt is exactly `prompt_len` tokens and ends with
    [QUERY, key]; the model's next token should be the answer value.
    """
    assert prompt_len >= 8 and prompt_len % 2 == 0
    vmap = sample_mapping(rng)
    qk = int(rng.integers(0, N_KEYS))
    n_pairs = (prompt_len - 2) // 2
    needle_at = max(0, min(n_pairs - 1, int(n_pairs * needle_frac)))
    toks = []
    for p in range(n_pairs):
        if p == needle_at:
            k = qk
        else:
            # distractors: any key except the queried one
            k = int(rng.integers(0, N_KEYS - 1))
            if k >= qk:
                k += 1
        toks += [KEY_BASE + k, int(vmap[k])]
    toks += [QUERY, KEY_BASE + qk]
    answer = int(vmap[qk])
    k_pos = 2 * needle_at
    return toks, answer, (k_pos, k_pos + 1)
