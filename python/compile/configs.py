"""Model and artifact-matrix configuration shared by model.py / aot.py /
train.py and the pytest suite.

The three `sim-*` configs are scaled stand-ins for the paper's
Llama-3.2-1B / 3.2-3B / 3.1-8B (same architecture family: RMSNorm, RoPE,
GQA, SwiGLU; see DESIGN.md §4 for the substitution rationale). `sim-1b`
is additionally *trained* on an associative-recall byte task by train.py so
that accuracy-vs-budget curves are measured on a model that actually uses
its long context.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    max_seq_len: int = 1024

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def weight_names(self) -> List[str]:
        """Canonical flattened weight order — the runtime ABI.

        Rust feeds weights to every graph in exactly this order, after the
        runtime inputs.
        """
        names = ["emb"]
        for i in range(self.n_layers):
            for w in (
                "attn_norm", "wq", "wk", "wv", "wo",
                "mlp_norm", "w_gate", "w_up", "w_down",
            ):
                names.append(f"layer{i}.{w}")
        names += ["out_norm", "head"]
        return names

    def weight_shapes(self) -> List[Tuple[int, ...]]:
        shapes = [(self.vocab_size, self.d_model)]
        for _ in range(self.n_layers):
            shapes += [
                (self.d_model,),
                (self.d_model, self.q_dim),
                (self.d_model, self.kv_dim),
                (self.d_model, self.kv_dim),
                (self.q_dim, self.d_model),
                (self.d_model,),
                (self.d_model, self.d_ff),
                (self.d_model, self.d_ff),
                (self.d_ff, self.d_model),
            ]
        shapes += [(self.d_model,), (self.d_model, self.vocab_size)]
        return shapes

    def n_params(self) -> int:
        return sum(int(__import__("math").prod(s)) for s in self.weight_shapes())


# Scaled stand-ins for Llama-3.2-1B / 3.2-3B / 3.1-8B (DESIGN.md §4).
SIM_1B = ModelConfig(
    name="sim-1b", vocab_size=256, d_model=64, n_layers=2,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=256,
)
SIM_3B = ModelConfig(
    name="sim-3b", vocab_size=256, d_model=128, n_layers=4,
    n_heads=4, n_kv_heads=2, d_head=32, d_ff=512,
)
SIM_8B = ModelConfig(
    name="sim-8b", vocab_size=256, d_model=256, n_layers=6,
    n_heads=8, n_kv_heads=2, d_head=32, d_ff=1024,
)

MODELS = {c.name: c for c in (SIM_1B, SIM_3B, SIM_8B)}

# ---------------------------------------------------------------------------
# Artifact matrix (DESIGN.md §2): which graphs `make artifacts` lowers.
# ---------------------------------------------------------------------------

# Prompt-length buckets for the prefill graph.
PREFILL_BUCKETS = [64, 128, 256, 512]
# Context-token buckets for the decode graph (page-count = bucket/page_size).
DECODE_BUCKETS = [128, 256, 512, 768, 1024]
# vLLM's default page size (paper §5.1) plus the Fig-4 ablation sizes.
DEFAULT_PAGE_SIZE = 16
ABLATION_PAGE_SIZES = [8, 32]
# Decode buckets lowered for the ablation page sizes (keep the matrix small).
ABLATION_DECODE_BUCKETS = [256, 512, 1024]
# Batched decode (one padded dispatch for the whole running set): lanes per
# dispatch and the context buckets lowered at the default page size.
DECODE_BATCH_LANES = 8
DECODE_BATCH_BUCKETS = [256, 512]


@dataclass(frozen=True)
class GraphSpec:
    """One AOT-lowered graph: (kind, model, static shape params)."""
    kind: str           # "prefill" | "decode" | "decode_batch"
    model: str
    seq_bucket: int     # prefill: P; decode: context-token bucket
    page_size: int = DEFAULT_PAGE_SIZE  # decode only
    batch: int = 0      # decode_batch only: lanes per dispatch

    @property
    def n_blocks(self) -> int:
        assert self.kind in ("decode", "decode_batch")
        assert self.seq_bucket % self.page_size == 0
        return self.seq_bucket // self.page_size

    @property
    def artifact_name(self) -> str:
        if self.kind == "prefill":
            return f"prefill_{self.model}_p{self.seq_bucket}"
        if self.kind == "decode_batch":
            return (f"decodeb{self.batch}_{self.model}"
                    f"_c{self.seq_bucket}_b{self.page_size}")
        return f"decode_{self.model}_c{self.seq_bucket}_b{self.page_size}"


def artifact_matrix(models=None) -> List[GraphSpec]:
    specs: List[GraphSpec] = []
    for m in (models or MODELS):
        for p in PREFILL_BUCKETS:
            specs.append(GraphSpec("prefill", m, p))
        for c in DECODE_BUCKETS:
            specs.append(GraphSpec("decode", m, c, DEFAULT_PAGE_SIZE))
        for ps in ABLATION_PAGE_SIZES:
            for c in ABLATION_DECODE_BUCKETS:
                specs.append(GraphSpec("decode", m, c, ps))
        for c in DECODE_BATCH_BUCKETS:
            specs.append(GraphSpec("decode_batch", m, c, DEFAULT_PAGE_SIZE,
                                   batch=DECODE_BATCH_LANES))
    return specs
