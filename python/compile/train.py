"""Build-time trainer: teach sim-1b associative recall so the accuracy
benches measure a model that genuinely uses its long context.

Runs once (`make train`, ~10-15 min on 1 CPU core), writes
artifacts/sim-1b.trained.bin; aot.py prefers trained weights when present.

Usage: python -m compile.train --out ../artifacts [--steps N]
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, model, recall_task

CFG = configs.SIM_1B


def forward_logits(cfg, params, tokens):
    """Training forward: all-position logits. tokens: [B, S] i32.
    Reuses the exact inference building blocks (jnp attention path)."""
    emb, layers, out_norm, head = model._unpack_layers(
        cfg, [params[n] for n in cfg.weight_names()]
    )

    def one(seq):
        s = seq.shape[0]
        positions = jnp.arange(s, dtype=jnp.int32)
        h = emb[seq]
        from .kernels import ref as kref
        for layer in layers:
            x = model.rms_norm(h, layer["attn_norm"], cfg.norm_eps)
            q, k, v = model._attn_proj(cfg, x, layer, positions)
            attn = kref.causal_attention_ref(q, k, v, s)
            h = h + attn.transpose(1, 0, 2).reshape(s, cfg.q_dim) @ layer["wo"]
            x = model.rms_norm(h, layer["mlp_norm"], cfg.norm_eps)
            h = h + model._mlp(x, layer)
        h = model.rms_norm(h, out_norm, cfg.norm_eps)
        return h @ head

    return jax.vmap(one)(tokens)


def loss_fn(params, cfg, tokens, mask):
    logits = forward_logits(cfg, params, tokens)  # [B, S, V]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    w = mask[:, 1:]
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        m_k = b1 * m[k] + (1 - b1) * grads[k]
        v_k = b2 * v[k] + (1 - b2) * grads[k] ** 2
        mhat = m_k / (1 - b1 ** step)
        vhat = v_k / (1 - b2 ** step)
        out_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        out_m[k], out_v[k] = m_k, v_k
    return out_p, out_m, out_v


@functools.partial(jax.jit, static_argnums=(5,))
def train_step(params, m, v, batch, step_lr, cfg):
    tokens, mask, step, lr = batch
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens, mask)
    params, m, v = adam_update(params, grads, m, v, step, lr)
    return params, m, v, loss


def eval_recall(params, cfg, rng, n_prompts=32, prompt_len=192) -> float:
    """Greedy one-token answer accuracy on needle prompts (full cache)."""
    hits = 0
    for _ in range(n_prompts):
        toks, ans, _ = recall_task.make_eval_prompt(rng, prompt_len)
        logits = forward_logits(
            cfg, params, jnp.asarray([toks], jnp.int32)
        )[0, -1]
        hits += int(int(jnp.argmax(logits)) == ans)
    return hits / n_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=1400)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    weights = model.init_weights(CFG, seed=42)
    params = {k: jnp.asarray(w) for k, w in weights.items()}
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}

    t0 = time.time()
    for step in range(1, args.steps + 1):
        warm = min(1.0, step / 100.0)
        decay = 0.5 * (1 + np.cos(np.pi * step / args.steps))
        lr = args.lr * warm * (0.1 + 0.9 * decay)
        toks, mask = recall_task.make_training_batch(rng, args.batch, args.seq)
        params, m, v, loss = train_step(
            params, m, v,
            (jnp.asarray(toks), jnp.asarray(mask),
             jnp.float32(step), jnp.float32(lr)),
            None, CFG,
        )
        if step % 100 == 0 or step == 1:
            acc = eval_recall(params, CFG, np.random.default_rng(123))
            print(f"[train] step {step:5d} loss {float(loss):.4f} "
                  f"recall@192 {acc:.2f} lr {lr:.2e} "
                  f"({time.time() - t0:.0f}s)", flush=True)

    acc = eval_recall(params, CFG, np.random.default_rng(123), n_prompts=64)
    print(f"[train] final recall@192 = {acc:.3f}")
    out = {k: np.asarray(p) for k, p in params.items()}
    path = f"{args.out}/sim-1b.trained.bin"
    model.save_weights(path, out, CFG.weight_names())
    print(f"[train] wrote {path}")


if __name__ == "__main__":
    main()
